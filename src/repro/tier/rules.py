"""Decision-rule core shared by every tier engine (docs/tier.md §Rules).

The four paper policies, reduced to three orthogonal questions answered once
here and executed by both engines:

  eligible()         which rows may be promoted at all
  victim_order_key() which resident row is displaced first
  accept()           whether a planned (candidate, victim) migration pays

  SC  (Simple Caching)        : every accessed far row; LRU victim; always.
  WMC (Wait-Minimized Caching): like SC, but only while the bank is idle so
        the inter-segment transfer never delays a pending request.
  BBC (Benefit-Based Caching) : rows with sustained reuse; minimum-retained-
        benefit victim; only when the candidate's expected benefit (decayed
        activation count x saving per access) clears the victim's benefit
        plus the hysteresis-scaled migration cost.  The paper's best policy.
  STATIC (OS-exposed)         : profile-driven placement at t=0, no runtime
        migration (the paper's second approach).

Every function takes the array namespace ``xp`` (``numpy`` or ``jax.numpy``)
so the nanosecond-substrate engine (`repro.tier.engine`) and the jittable TPU
engine (`repro.tier.jax_engine`) run the *same* policy arithmetic — asserted
by the stream-replay parity tests in ``tests/test_tier_parity.py``.
"""

from __future__ import annotations

import numpy as np

from repro.tier.costs import TierCosts

POLICY_NAMES = ("SC", "WMC", "BBC", "STATIC")

_NEG_INF = float("-inf")

# Scores below this after decay are treated as zero (dead entries).
SCORE_FLOOR = 1e-3


def ema_update(scores, activations, costs: TierCosts):
    """Decayed activation counts: scores, activations are (..., N) arrays."""
    return scores * costs.decay + activations


def benefit(scores, costs: TierCosts):
    """Expected benefit of near residency: activations x saving per access."""
    return scores * costs.saving


def eligible(policy: str, scores, accessed, costs: TierCosts, xp):
    """Which rows may be promoted.  ``accessed`` marks rows activated in the
    current access (per-access mode) or interval (interval mode)."""
    if policy in ("SC", "WMC"):
        return accessed
    if policy == "BBC":
        return accessed & (scores >= costs.min_score)
    if policy == "STATIC":
        return xp.zeros_like(accessed)
    raise ValueError(f"unknown policy {policy!r}")


def victim_order_key(policy: str, scores, last_use):
    """Per-row key the victim search minimizes: LRU time for SC/WMC,
    retained benefit (== score, up to the constant saving factor) for BBC."""
    if policy in ("SC", "WMC"):
        return last_use
    return scores


def accept(policy: str, cand_score, victim_score, victim_dirty, victim_empty,
           idle, costs: TierCosts, xp):
    """Whether a planned (candidate, victim) migration goes ahead.

    All score/flag arguments broadcast; the result broadcasts against them
    (SC/WMC/STATIC return scalars).  ``idle`` may be a traced scalar (WMC's
    bank-idle gate).
    """
    if policy == "SC":
        return True
    if policy == "WMC":
        return idle
    if policy == "STATIC":
        return False
    # BBC.  A dirty victim needs a write-back IST on top of the fill IST; an
    # empty slot only needs the candidate to pay for its own migration.
    cand_b = benefit(cand_score, costs)
    victim_b = benefit(victim_score, costs)
    ist = costs.migrate_cost * xp.where(victim_dirty, 2.0, 1.0)
    margin = xp.where(victim_empty, costs.migrate_cost,
                      victim_b + ist * costs.hysteresis)
    return cand_b > margin


def top_k(xp, x, k: int):
    """Descending top-k with index-ascending tie-break on both backends."""
    if xp is np:
        idx = np.argsort(-x, kind="stable")[:k].astype(np.int32)
        return x[idx], idx
    import jax
    return jax.lax.top_k(x, k)


def plan_promotions_xp(xp, policy: str, scores, slot_of_row, row_of_slot,
                       costs: TierCosts, max_promotions: int, *,
                       last_use=None, accessed=None, idle=True, dirty=None):
    """One interval-mode planning step over a row population.

    scores      : (N,) decayed activation counts per row.
    slot_of_row : (N,) int32 — near slot per row, -1 if far-resident.
    row_of_slot : (C,) int32 — far row per near slot, -1 if empty.
    last_use    : (N,) optional recency stamps (required for exact SC/WMC
                  LRU victims; scores are used as a decayed-recency proxy
                  when absent).
    accessed    : (N,) optional bool mask of rows activated this interval
                  (defaults to ``scores > 0``).
    dirty       : (N,) optional bool mask of dirty near rows (write-back
                  IST accounting for BBC; substrates with immutable rows,
                  like KV pages, leave it None).

    Returns ``(promote_rows (K,), victim_slots (K,), valid (K,))``: rows to
    migrate and the slots to place them in.  Promotions fill empty slots
    first, then displace victims in the policy's eviction order.
    """
    policy = policy.upper()
    if policy not in POLICY_NAMES:
        raise ValueError(f"unknown policy {policy!r}")
    in_near = slot_of_row >= 0
    if accessed is None:
        accessed = scores > 0.0
    elig = eligible(policy, scores, accessed, costs, xp) & ~in_near
    cand_rank = xp.where(elig, scores, _NEG_INF)
    top_scores, top_rows = top_k(xp, cand_rank, max_promotions)

    slot_empty = row_of_slot < 0
    safe_rows = xp.maximum(row_of_slot, 0)
    vkey_rows = victim_order_key(
        policy, scores, last_use if last_use is not None else scores)
    vkey = xp.where(slot_empty, _NEG_INF, vkey_rows[safe_rows])
    # Victims: empty slots first (-inf key sorts first under -vkey), then the
    # policy's eviction order, ties broken towards lower slot index.
    _, victim_slots = top_k(xp, -vkey, max_promotions)
    victim_is_empty = slot_empty[victim_slots]
    victim_scores = xp.where(victim_is_empty, 0.0,
                             scores[safe_rows][victim_slots])
    if dirty is None:
        victim_dirty = xp.zeros_like(victim_is_empty)
    else:
        victim_dirty = dirty[safe_rows][victim_slots] & ~victim_is_empty
    ok = accept(policy, xp.where(xp.isfinite(top_scores), top_scores, 0.0),
                victim_scores, victim_dirty, victim_is_empty, idle, costs, xp)
    valid = ok & xp.isfinite(top_scores)
    return top_rows, victim_slots, valid
