"""Synthetic workload trace generation for the DRAM simulator.

The paper evaluates with SPEC CPU2006 traces (not redistributable).  We
generate calibrated synthetic mixes with the properties that drive the
TL-DRAM result: Zipfian row popularity (a small hot set of rows dominates),
row-buffer burst locality, and a range of memory intensities (MPKI).

Workload classes (named after the paper's benchmark behaviour classes):

  hot      : memory-intensive, highly skewed row reuse   (caching-friendly)
  stream   : memory-intensive, sequential row sweeps     (low reuse)
  mixed    : moderate intensity, skewed + streaming blend
  uniform  : memory-intensive, uniform random rows       (caching-adverse)
  light    : low memory intensity (compute-bound)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.simulator import Trace


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    n_requests: int = 30_000
    mpki: float = 25.0               # memory requests per kilo-instruction
    zipf_alpha: float = 1.3          # row-popularity skew (0 => uniform)
    working_set_rows: int = 1024     # distinct rows touched
    burst_geo_p: float = 0.35        # P(end burst): row-buffer burst locality
    stream_frac: float = 0.0         # fraction of requests from a row sweep
    write_frac: float = 0.30
    banks: int = 8
    subarrays: int = 16
    rows_per_subarray: int = 480     # TL-DRAM far address space
    # OS page allocation clusters spatially: the working set concentrates in
    # a few (bank, subarray) regions, so per-subarray near capacity binds.
    subarrays_used: int = 20         # of banks*subarrays total regions


CLASSES: dict[str, WorkloadSpec] = {
    # SPEC-memory-intensive-like: strong row reuse at the 10k-cycle scale
    # (Zipfian hot set) but modest row-buffer *burst* locality (row-hit
    # rates around 40-60%, as measured for SPEC CPU2006).
    "hot": WorkloadSpec("hot", mpki=30.0, zipf_alpha=1.6,
                        working_set_rows=768, burst_geo_p=0.70),
    "hot2": WorkloadSpec("hot2", mpki=22.0, zipf_alpha=1.8,
                         working_set_rows=512, burst_geo_p=0.65),
    "mixed": WorkloadSpec("mixed", mpki=15.0, zipf_alpha=1.4,
                          working_set_rows=1024, burst_geo_p=0.65,
                          stream_frac=0.10),
    "light": WorkloadSpec("light", mpki=8.0, zipf_alpha=1.6,
                          working_set_rows=512, burst_geo_p=0.65),
    # Fig-9 class: flatter popularity and a bigger hot set, so near-segment
    # *capacity* binds (the capacity-vs-latency trade-off of the sweep).
    "capacity": WorkloadSpec("capacity", mpki=25.0, zipf_alpha=1.05,
                             working_set_rows=2048, burst_geo_p=0.65,
                             subarrays_used=16),
    # Adversarial tails (low reuse): TL-DRAM gains little / loses here.
    "stream": WorkloadSpec("stream", mpki=28.0, zipf_alpha=0.4,
                           working_set_rows=4096, burst_geo_p=0.45,
                           stream_frac=0.8),
    "uniform": WorkloadSpec("uniform", mpki=25.0, zipf_alpha=0.0,
                            working_set_rows=8192, burst_geo_p=0.6),
}

# The paper's multiprogrammed mixes draw from all behaviour classes.
DEFAULT_MIX = ("hot", "mixed", "hot", "stream")


def _zipf_rows(rng: np.ndarray, spec: WorkloadSpec, n: int) -> np.ndarray:
    """Sample row *identities* (0..working_set-1) with Zipfian popularity."""
    ws = spec.working_set_rows
    if spec.zipf_alpha <= 0.0:
        return rng.integers(0, ws, size=n)
    ranks = np.arange(1, ws + 1, dtype=np.float64)
    p = ranks ** (-spec.zipf_alpha)
    p /= p.sum()
    return rng.choice(ws, size=n, p=p)


def generate(spec: WorkloadSpec, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    n = spec.n_requests

    # --- row identity stream: bursts of the same row (row-buffer locality),
    # with a streaming component sweeping rows sequentially.
    n_bursts = max(1, int(n * spec.burst_geo_p))
    burst_rows = _zipf_rows(rng, spec, n_bursts)
    burst_lens = rng.geometric(spec.burst_geo_p, size=n_bursts)
    rows_ws = np.repeat(burst_rows, burst_lens)[:n]
    if len(rows_ws) < n:
        extra = _zipf_rows(rng, spec, n - len(rows_ws))
        rows_ws = np.concatenate([rows_ws, extra])

    if spec.stream_frac > 0:
        n_stream = int(n * spec.stream_frac)
        idx = np.sort(rng.choice(n, size=n_stream, replace=False))
        rows_ws[idx] = (np.arange(n_stream) // 4) % spec.working_set_rows

    # --- map working-set row identity -> (bank, subarray, row).  A fixed
    # random layout per workload, clustered into ``subarrays_used`` regions
    # (page-coloring-like spatial locality).
    ws = spec.working_set_rows
    n_regions = spec.banks * spec.subarrays
    used = rng.choice(n_regions, size=min(spec.subarrays_used, n_regions),
                      replace=False)
    region_of_row = used[rng.integers(0, len(used), size=ws)]
    row_in_region = rng.integers(0, spec.rows_per_subarray, size=ws)
    flat_region = region_of_row[rows_ws]
    banks = flat_region % spec.banks
    subarrays = flat_region // spec.banks
    rows = row_in_region[rows_ws]

    # --- instruction gaps from MPKI: mean gap = 1000/MPKI non-mem instrs.
    mean_gap = max(1.0, 1000.0 / spec.mpki - 1.0)
    gaps = rng.exponential(mean_gap, size=n).astype(np.int64)

    writes = rng.random(n) < spec.write_frac

    return Trace(gaps=gaps, banks=banks.astype(np.int64),
                 subarrays=subarrays.astype(np.int64),
                 rows=rows.astype(np.int64), writes=writes)


def make_mix(names: tuple[str, ...] = DEFAULT_MIX, n_requests: int | None = None,
             seed: int = 0) -> list[Trace]:
    """A multiprogrammed workload: one trace per core."""
    out = []
    for i, name in enumerate(names):
        spec = CLASSES[name]
        if n_requests is not None:
            spec = WorkloadSpec(**{**spec.__dict__, "n_requests": n_requests})
        out.append(generate(spec, seed=seed * 1000 + i))
    return out
