"""DRAM power/energy model for TL-DRAM.

Bitline charging dominates DRAM array power; TL-DRAM scales it with the number
of driven cells, plus an isolation-FET toggle penalty for far-segment accesses
(paper Sec. 3, Table 1).

Normalized access-energy coefficients are fitted to Table 1 of the paper
(values derived there from the Rambus power model [107]):

    E_norm(near/unsegmented, n cells driven) = beta + alpha * n
    E_norm(far)  = beta + alpha * (n_near + n_far) + gamma_iso

with anchors  short-32/near-32 = 0.51,  long-512 = 1.00,  far-480 = 1.49:

    alpha = 0.49/480 per cell,  beta = 0.477333,  gamma_iso = 0.49

Absolute energies follow DDR3-2Gb-class devices so that the simulator's power
breakdown is realistic (activation ~40%, read/write ~25%, background ~30%,
refresh ~5% for a memory-intensive workload on commodity DDR3).
"""

from __future__ import annotations

from dataclasses import dataclass

# Fitted normalized coefficients (see module docstring).
ALPHA_PER_CELL = 0.49 / 480.0
BETA_FIXED = 0.51 - 32 * ALPHA_PER_CELL
GAMMA_ISO = 0.49

# Absolute energy scale: a long-bitline (normalized 1.0) ACT+PRE pair for one
# rank (8x x8 2Gb chips).  The paper's power model (Rambus [107]) is
# array-centric: "a large fraction of the power is consumed by the bitlines"
# (Sec. 3) — activation/precharge dominates, column/I-O and standby are minor
# for memory-intensive workloads.
E_ACT_PRE_LONG_NJ = 28.0

# Non-array energies (per 64B column burst / rank level, array-centric model:
# controller-side I/O termination is outside the DRAM power envelope here).
E_READ_NJ = 1.5           # column read burst (column path + I/O)
E_WRITE_NJ = 1.8          # column write burst
E_REFRESH_PER_ROW_NJ = 2.5
E_IST_EXTRA_NJ = 2.0      # inter-segment transfer: extra 4ns drive on bitlines
P_BACKGROUND_MW = 15.0    # standby/periphery (power-down modes assumed)


@dataclass(frozen=True)
class AccessEnergy:
    """Per-operation energies (nJ) for one device configuration."""

    act_pre_nj: float      # one ACTIVATE+PRECHARGE pair
    read_nj: float = E_READ_NJ
    write_nj: float = E_WRITE_NJ


def act_pre_energy_norm(cells_driven: int, iso_toggled: bool = False) -> float:
    """Normalized ACT+PRE energy (long-512 bitline == 1.0)."""
    e = BETA_FIXED + ALPHA_PER_CELL * cells_driven
    if iso_toggled:
        e += GAMMA_ISO
    return e


def act_pre_energy_nj(cells_driven: int, iso_toggled: bool = False) -> float:
    return E_ACT_PRE_LONG_NJ * act_pre_energy_norm(cells_driven, iso_toggled)


def near_access_energy(near_cells: int) -> AccessEnergy:
    """Near-segment access: iso FET off, only the near segment is driven."""
    return AccessEnergy(act_pre_nj=act_pre_energy_nj(near_cells, iso_toggled=False))


def far_access_energy(near_cells: int, far_cells: int) -> AccessEnergy:
    """Far-segment access: the whole bitline is driven through the iso FET."""
    return AccessEnergy(
        act_pre_nj=act_pre_energy_nj(near_cells + far_cells, iso_toggled=True))


def unsegmented_access_energy(cells: int) -> AccessEnergy:
    return AccessEnergy(act_pre_nj=act_pre_energy_nj(cells))


def ist_energy_nj(near_cells: int, far_cells: int) -> float:
    """Inter-segment transfer: a far access (source restore drives both
    segments) plus the extra ~4ns of bitline drive into the destination row."""
    return act_pre_energy_nj(near_cells + far_cells, iso_toggled=True) + E_IST_EXTRA_NJ


def table1_power_norm() -> dict[str, float]:
    """Reproduces the 'Normalized Power' row of Table 1."""
    return {
        "short_32": act_pre_energy_norm(32),
        "long_512": act_pre_energy_norm(512),
        "near_32": act_pre_energy_norm(32),
        "far_480": act_pre_energy_norm(512, iso_toggled=True),
    }
