"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
fault tolerance, elastic resharding."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import InputShape
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw, compression
from repro.runtime import elastic
from repro.runtime.fault_tolerance import (Heartbeat, RetryPolicy,
                                           StragglerDetector, run_supervised)

SHAPE = InputShape("t", seq_len=32, global_batch=4, kind="train")


class TestDataPipeline:
    def test_deterministic_across_instances(self):
        arch = ARCHS["qwen3-1.7b"].reduced()
        a = SyntheticLM(arch, SHAPE).batch(7)
        b = SyntheticLM(arch, SHAPE).batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_partition_global_batch(self):
        arch = ARCHS["qwen3-1.7b"].reduced()
        full = SyntheticLM(arch, SHAPE, rank=0, world=1).batch(3)
        r0 = SyntheticLM(arch, SHAPE, rank=0, world=2).batch(3)
        r1 = SyntheticLM(arch, SHAPE, rank=1, world=2).batch(3)
        np.testing.assert_array_equal(
            np.concatenate([r0["tokens"], r1["tokens"]]), full["tokens"])

    def test_labels_shift_tokens(self):
        arch = ARCHS["qwen3-1.7b"].reduced()
        b = SyntheticLM(arch, SHAPE).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_modality_batches(self):
        for name in ("qwen2-vl-2b", "musicgen-medium"):
            arch = ARCHS[name].reduced()
            b = SyntheticLM(arch, SHAPE).batch(0)
            if name == "qwen2-vl-2b":
                assert "patch_embeds" in b and "positions" in b
            else:
                assert "frame_embeds" in b and b["labels"].ndim == 3


class TestAdamW:
    def _params(self):
        k = jax.random.key(0)
        return {"w": jax.random.normal(k, (64, 32)),
                "b": jnp.zeros((32,))}

    @pytest.mark.parametrize("moment_dtype", ["f32", "int8"])
    def test_converges_on_quadratic(self, moment_dtype):
        cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                                moment_dtype=moment_dtype)
        params = self._params()
        target = jax.tree.map(lambda p: jnp.ones_like(p), params)
        state = adamw.init(params, cfg)

        def loss(p):
            return sum(jnp.sum((a - t) ** 2) for a, t in
                       zip(jax.tree.leaves(p), jax.tree.leaves(target)))

        l0 = float(loss(params))
        for _ in range(60):
            grads = jax.grad(loss)(params)
            params, state, _ = adamw.update(params, grads, state, cfg, cfg.lr)
        assert float(loss(params)) < 0.05 * l0

    def test_int8_state_is_smaller(self):
        params = {"w": jnp.zeros((1024, 256))}
        s8 = adamw.init(params, adamw.AdamWConfig(moment_dtype="int8"))
        s32 = adamw.init(params, adamw.AdamWConfig(moment_dtype="f32"))
        bytes8 = sum(np.asarray(x).nbytes for x in jax.tree.leaves(s8))
        bytes32 = sum(np.asarray(x).nbytes for x in jax.tree.leaves(s32))
        assert bytes8 < 0.35 * bytes32

    def test_grad_clipping(self):
        cfg = adamw.AdamWConfig(grad_clip=1.0)
        params = self._params()
        state = adamw.init(params, cfg)
        grads = jax.tree.map(lambda p: 1e6 * jnp.ones_like(p), params)
        new_params, _, m = adamw.update(params, grads, state, cfg, 1e-3)
        assert float(m["grad_norm"]) > 1e5
        delta = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                    zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
        assert delta < 0.1  # clipped update is bounded


class TestGradCompression:
    def test_error_feedback_unbiased_over_steps(self):
        """Accumulated compressed updates converge to accumulated truth."""
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        g = jax.random.normal(jax.random.key(0), (256,))
        residual = {"g": jnp.zeros((256,))}
        total_c = jnp.zeros((256,))
        total_t = jnp.zeros((256,))
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        @partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                 out_specs=(P(), P()), check_rep=False)
        def step(gi, r):
            out, new_r = compression.compress_psum(
                {"g": gi}, {"g": r}, ("data",))
            return out["g"], new_r["g"]

        r = residual["g"]
        for i in range(20):
            gi = g * (1.0 + 0.01 * i)
            out, r = step(gi, r)
            total_c += out
            total_t += gi
        err = float(jnp.linalg.norm(total_c - total_t)
                    / jnp.linalg.norm(total_t))
        assert err < 0.02, err

    def test_wire_format_is_int8(self):
        """The all-reduced payload must be 8-bit (4x compression)."""
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        @partial(shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
                 check_rep=False)
        def f(g):
            out, _ = compression.compress_psum(
                {"g": g}, {"g": jnp.zeros_like(g)}, ("data",))
            return out["g"]

        txt = jax.jit(f).lower(jnp.zeros((1024,))).as_text()
        assert "s8" in txt or "i8" in txt


class TestCheckpointManager:
    def _tree(self, x=1.0):
        return {"a": jnp.full((8, 8), x), "b": {"c": jnp.arange(5)}}

    def test_roundtrip(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save(10, self._tree(2.0), extra={"data_step": 10})
        tree, extra = m.restore(self._tree())
        np.testing.assert_allclose(tree["a"], 2.0)
        assert extra["data_step"] == 10

    def test_async_save(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save(1, self._tree(3.0), blocking=False)
        m.wait()
        tree, _ = m.restore(self._tree())
        np.testing.assert_allclose(tree["a"], 3.0)

    def test_atomic_no_partial_checkpoint(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save(1, self._tree())
        assert not list(tmp_path.glob("*.tmp"))

    def test_retention(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            m.save(s, self._tree(float(s)))
        assert m.all_steps() == [3, 4]

    def test_corruption_detected_and_fallback(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=5)
        m.save(1, self._tree(1.0))
        m.save(2, self._tree(2.0))
        # corrupt the newest checkpoint
        victim = next((tmp_path / "step_0000000002").rglob("leaf_00000.npy"))
        arr = np.load(victim)
        np.save(victim, arr + 1)
        with pytest.raises(IOError):
            m.restore(self._tree(), 2)
        tree, _ = m.restore_with_fallback(self._tree())
        np.testing.assert_allclose(tree["a"], 1.0)  # fell back to step 1


class TestFaultTolerance:
    def test_straggler_detection(self):
        det = StragglerDetector(strikes_to_flag=3)
        flagged = []
        for step in range(10):
            times = {f"host{i}": 1.0 + 0.01 * i for i in range(16)}
            times["host7"] = 5.0  # persistent straggler
            flagged = det.observe_step(times)
        assert flagged == ["host7"]

    def test_transient_blip_not_flagged(self):
        det = StragglerDetector(strikes_to_flag=3)
        for step in range(10):
            times = {f"host{i}": 1.0 for i in range(16)}
            if step == 4:
                times["host3"] = 9.0  # single blip
            assert det.observe_step(times) == []

    def test_run_supervised_restarts(self):
        calls = {"n": 0}

        def loop():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("simulated node failure")
            return 100, "state"

        step, state = run_supervised(loop, None, RetryPolicy(backoff_s=0.0))
        assert step == 100 and calls["n"] == 3

    def test_heartbeat(self):
        hb = Heartbeat(timeout_s=10.0)
        hb.beat("a", now=0.0)
        hb.beat("b", now=8.0)
        assert hb.dead_hosts(now=11.0) == ["a"]


class TestElastic:
    def test_plan_shrinks_dp_preserves_tp(self):
        plan = elastic.plan_mesh(n_devices=192, model_parallel=16,
                                 target_dp=16)
        assert plan.mesh_shape == (12, 16) or plan.mesh_shape[1] == 16
        assert plan.dp_size * plan.grad_accum >= 16

    def test_plan_exact_fit(self):
        plan = elastic.plan_mesh(256, 16, 16)
        assert plan.mesh_shape == (16, 16)
        assert plan.grad_accum == 1

    def test_plan_rejects_too_few(self):
        with pytest.raises(ValueError):
            elastic.plan_mesh(8, 16, 16)

    def test_reshard_on_local_devices(self):
        arch = ARCHS["qwen3-1.7b"].reduced()
        from repro.models import transformer
        params = transformer.init_params(jax.random.key(0), arch)
        plan = elastic.plan_mesh(len(jax.devices()), 1,
                                 target_dp=len(jax.devices()))
        mesh = elastic.build_mesh(plan)
        placed = elastic.reshard(params, arch, mesh)
        for a, b in zip(jax.tree.leaves(placed), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
