"""HLO-text analysis: FLOPs, HBM traffic, and collective wire bytes with
correct while-loop accounting.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*, which
under-reports every scan-over-layers model by ~L.  This module re-derives the
three roofline inputs from the HLO text itself:

  * computations parse into blocks with a per-computation symbol table
    (instruction name -> shape), so dot contracting dims resolve even though
    operand shapes are not printed inline;
  * ``while`` ops multiply their body's totals by the trip count from the
    instruction's ``backend_config known_trip_count`` (emitted by XLA for
    scan loops), falling back to the loop-condition constant;
  * FLOPs: 2 * |out| * prod(contracting dims) per dot/convolution, plus
    1 flop/element for elementwise fusions (minor, counted for honesty);
  * HBM traffic: operand + result bytes at fusion/dot/data-movement
    boundaries of the post-fusion HLO;
  * collective wire bytes per device with ring-algorithm factors:
      all-reduce          2 (n-1)/n * payload
      all-gather          (n-1)/n * payload      (payload = gathered output)
      reduce-scatter      (n-1)   * payload      (payload = scattered shard)
      all-to-all          (n-1)/n * payload
      collective-permute  1       * payload

Validated against analytic 6ND in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import re

import numpy as np
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# numpy/JAX dtype name -> HLO shape-string dtype name.  One byte table
# (above) serves both the HLO-text parser and aval-level byte accounting
# (the repro.analysis vmem-budget pass) so the two can never drift.
_NUMPY_TO_HLO = {
    "bool": "pred", "int8": "s8", "uint8": "u8", "int16": "s16",
    "uint16": "u16", "float16": "f16", "bfloat16": "bf16", "int32": "s32",
    "uint32": "u32", "float32": "f32", "int64": "s64", "uint64": "u64",
    "float64": "f64", "complex64": "c64", "complex128": "c128",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
}

DTYPE_BYTES = dict(_DTYPE_BYTES)   # public view of the byte table


def hlo_dtype_name(dtype) -> str:
    """HLO shape-string name ('f32', 'bf16', ...) of a numpy/JAX dtype
    (np.dtype instances, scalar types like ``jnp.bfloat16``, or the HLO
    name itself)."""
    try:
        name = np.dtype(dtype).name
    except TypeError:
        name = getattr(dtype, "name", None) or str(dtype)
    if name in _DTYPE_BYTES:
        return name
    try:
        return _NUMPY_TO_HLO[name]
    except KeyError:
        raise ValueError(f"no HLO dtype name for {dtype!r}") from None


def dtype_bytes(dtype) -> int:
    """Bytes per element of a numpy/JAX dtype, via the HLO byte table."""
    return _DTYPE_BYTES[hlo_dtype_name(dtype)]


def aval_bytes(aval) -> int:
    """Total bytes of a shaped value (ShapedArray / ShapeDtypeStruct /
    ndarray): prod(shape) * dtype_bytes."""
    n = 1
    for d in aval.shape:
        n *= int(d)
    return n * dtype_bytes(aval.dtype)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|\w+\[[\d,]*\]\S*))\s+"
    r"([\w\-]+)\(([^)]*)\)(.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# HBM-traffic boundaries.  The CPU backend's HLO barely fuses elementwise
# chains, so counting every op's operands would overstate TPU traffic ~10x.
# We count the *structural* ops a TPU cannot fuse away — matmul operands and
# results, data movement, reductions, collectives — i.e. a perfect-fusion
# lower bound on HBM bytes (stated with the §Roofline tables).
_TRAFFIC_OPS = {
    "dot", "convolution", "copy", "transpose", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "slice",
    "reduce", "sort", "reverse", "custom-call",
} | set(COLLECTIVE_KINDS) | {k + "-start" for k in COLLECTIVE_KINDS}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


def _shape_elems(shape_str: str) -> int:
    n = 1
    for d in _shape_dims(shape_str):
        n *= d
    return max(n, 1)


@dataclass
class _Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    tail: str
    line: str


@dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_by_kind: dict = field(default_factory=lambda: defaultdict(
        lambda: [0, 0.0, 0.0]))
    calls: list = field(default_factory=list)  # (callee, 'while', trip)
    text: list = field(default_factory=list)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    if "source_target_pairs=" in line:
        return 2
    return 1


def _parse_instr(line: str) -> _Instr | None:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, shape, op, opnds, tail = m.groups()
    return _Instr(name=name, shape=shape, op=op,
                  operands=_OPERAND_RE.findall(opnds), tail=tail, line=line)


def _parse_computations(hlo: str):
    comps: dict[str, CompStats] = {}
    instrs: dict[str, list[_Instr]] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if stripped.endswith("{") and "(" in stripped and \
                "=" not in stripped.split("(", 1)[0]:
            name = stripped.split("(")[0].replace("ENTRY", "").strip() \
                .lstrip("%")
            cur = name
            comps[cur] = CompStats()
            instrs[cur] = []
            if stripped.startswith("ENTRY"):
                entry = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        comps[cur].text.append(stripped)
        inst = _parse_instr(stripped)
        if inst is not None:
            instrs[cur].append(inst)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comps, instrs, entry


def _accumulate(st: CompStats, insts: list[_Instr],
                cond_texts: dict[str, list[str]]) -> None:
    symbols = {i.name: i.shape for i in insts}
    for i in insts:
        op = i.op
        if op == "while":
            trip = 1
            m = _TRIP_RE.search(i.line)
            if m:
                trip = int(m.group(1))
            body = re.search(r"body=%?([\w.\-]+)", i.line)
            cond = re.search(r"condition=%?([\w.\-]+)", i.line)
            if trip == 1 and cond and cond.group(1) in cond_texts:
                for tline in cond_texts[cond.group(1)]:
                    if "compare" in tline:
                        for c in _CONST_RE.findall(tline):
                            trip = max(trip, int(c))
            if body:
                st.calls.append((body.group(1), "while", trip))
            continue

        out_bytes = _shape_bytes(i.shape)
        operand_bytes = sum(_shape_bytes(symbols.get(o, ""))
                            for o in i.operands)

        if op in ("dot", "convolution"):
            out_elems = _shape_elems(i.shape)
            k = 1
            cm = _LHS_CDIMS_RE.search(i.line)
            if cm and i.operands:
                lhs_dims = _shape_dims(symbols.get(i.operands[0], ""))
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
            st.flops += 2.0 * out_elems * k
        elif op == "fusion":
            st.flops += float(_shape_elems(i.shape))

        if op in _TRAFFIC_OPS:
            if op == "dynamic-update-slice":
                # in-place on TPU: traffic = the update slice (read+write),
                # not the whole aliased buffer
                upd = (_shape_bytes(symbols.get(i.operands[1], ""))
                       if len(i.operands) > 1 else out_bytes)
                st.bytes += 2 * upd
            elif op in ("gather", "dynamic-slice"):
                # reads only the gathered/sliced rows (+ writes the result)
                st.bytes += 2 * out_bytes
            else:
                st.bytes += out_bytes + operand_bytes

        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_KINDS and not op.endswith("-done"):
            payload = out_bytes
            n = _group_size(i.line)
            if base == "all-reduce":
                wire = 2.0 * (n - 1) / max(n, 1) * payload
            elif base == "all-gather":
                wire = (n - 1) / max(n, 1) * payload
            elif base == "reduce-scatter":
                wire = (n - 1) * payload
            elif base == "all-to-all":
                wire = (n - 1) / max(n, 1) * payload
            else:
                wire = payload
            rec = st.coll_by_kind[base]
            rec[0] += 1
            rec[1] += payload
            rec[2] += wire
            st.coll_wire += wire


@dataclass
class ModuleStats:
    flops: float
    bytes: float
    coll_wire_bytes: float
    coll_by_kind: dict
    trip_counts: dict

    def as_dict(self):
        return {
            "flops": self.flops, "bytes": self.bytes,
            "collective_wire_bytes": self.coll_wire_bytes,
            "collectives_by_kind": {
                k: dict(count=v[0], payload_bytes=v[1], wire_bytes=v[2])
                for k, v in self.coll_by_kind.items()},
            "while_trip_counts": self.trip_counts,
        }


def analyze_module(hlo: str) -> ModuleStats:
    comps, instrs, entry = _parse_computations(hlo)
    cond_texts = {name: c.text for name, c in comps.items()}
    for name, st in comps.items():
        _accumulate(st, instrs[name], cond_texts)

    trip_counts: dict[str, int] = {}
    memo: dict[str, tuple] = {}

    def total(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return (0.0, 0.0, 0.0, {})
        st = comps[name]
        f, b, w = st.flops, st.bytes, st.coll_wire
        kinds = {k: list(v) for k, v in st.coll_by_kind.items()}
        for callee, _, trip in st.calls:
            cf, cb, cw, ck = total(callee, stack + (name,))
            trip_counts[callee] = trip
            f += cf * trip
            b += cb * trip
            w += cw * trip
            for k, v in ck.items():
                rec = kinds.setdefault(k, [0, 0.0, 0.0])
                rec[0] += v[0] * trip
                rec[1] += v[1] * trip
                rec[2] += v[2] * trip
        memo[name] = (f, b, w, kinds)
        return memo[name]

    f, b, w, kinds = total(entry)
    return ModuleStats(flops=f, bytes=b, coll_wire_bytes=w,
                       coll_by_kind=kinds, trip_counts=trip_counts)
