"""Activation-sharding context: explicit constraints for model internals.

GSPMD propagates shardings from inputs/params, but scan carries, embedding
gathers, and losses can settle on pathological layouts (e.g. batch-replicated
activations when the embedding table is feature-sharded).  The launchers
install an ``ActivationSharding`` context; the model calls ``constrain`` at a
few anchor points (embedding output, layer-scan carry, logits) to pin the
batch/model axes.  Outside any context (CPU unit tests), ``constrain`` is a
no-op.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

BATCH = "__batch__"
MODEL = "__model__"
SEQ = "__seq__"     # sequence parallelism: shard over 'model' when enabled


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, batch_axes: tuple[str, ...] | None = None,
                        seq_shard: bool = False):
    """Install activation-sharding anchors for code lowered inside.

    seq_shard=True turns SEQ-role dims into 'model'-sharded (Megatron-style
    sequence parallelism: the per-layer TP all-reduces become
    reduce-scatter + all-gather pairs, halving activation wire bytes)."""
    if batch_axes is None:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    prev = getattr(_state, "cfg", None)
    _state.cfg = (mesh, batch_axes, seq_shard)
    try:
        yield
    finally:
        _state.cfg = prev


def constrain(x: jax.Array, *roles) -> jax.Array:
    """roles: one of BATCH, MODEL, SEQ, None per dimension of x.

    BATCH dims shard over the data axes (skipped when not divisible, e.g.
    batch-1 long-context decode); MODEL dims over 'model'; SEQ dims over
    'model' only when sequence parallelism is enabled.
    """
    cfg = getattr(_state, "cfg", None)
    if cfg is None:
        return x
    mesh, batch_axes, seq_shard = cfg
    batch_size = 1
    for a in batch_axes:
        batch_size *= mesh.shape[a]
    model_ok = "model" in mesh.shape
    spec = []
    for dim, role in enumerate(roles):
        if role == BATCH and batch_axes and x.shape[dim] % batch_size == 0:
            spec.append(batch_axes)
        elif role == MODEL and model_ok \
                and x.shape[dim] % mesh.shape["model"] == 0:
            spec.append("model")
        elif role == SEQ and seq_shard and model_ok \
                and x.shape[dim] % mesh.shape["model"] == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
