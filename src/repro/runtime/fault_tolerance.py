"""Fault-tolerance runtime: step supervision, straggler detection, retries.

At thousand-node scale three failure modes dominate; each has a handler:

  * crash/preemption   -> checkpoint/restart (`CheckpointManager` +
                          `run_supervised`'s retry loop);
  * stragglers         -> `StragglerDetector`: per-step wall-time EWMA with
                          robust z-scores; persistent outlier hosts are
                          reported for eviction (the elastic path);
  * data-loss on retry -> the deterministic pipeline recomputes any batch.

The detector is host-side and framework-agnostic: feed it (host, seconds)
samples per step — in a real fleet these arrive via the coordination service
heartbeats; tests feed synthetic distributions.
"""

from __future__ import annotations

import math
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    """Flags hosts whose step times are persistent robust outliers."""

    window: int = 32
    z_threshold: float = 4.0
    min_samples: int = 8
    strikes_to_flag: int = 3
    samples: dict = field(default_factory=lambda: defaultdict(
        lambda: deque(maxlen=64)))
    strikes: dict = field(default_factory=lambda: defaultdict(int))

    def observe_step(self, host_times: dict[str, float]) -> list[str]:
        """Record one step's per-host durations; returns hosts flagged."""
        times = sorted(host_times.values())
        n = len(times)
        if n < 2:
            return []
        median = times[n // 2]
        mad = sorted(abs(t - median) for t in times)[n // 2] + 1e-9
        flagged = []
        for host, t in host_times.items():
            self.samples[host].append(t)
            z = 0.6745 * (t - median) / mad
            if z > self.z_threshold and len(self.samples[host]) >= 1:
                self.strikes[host] += 1
            else:
                self.strikes[host] = max(0, self.strikes[host] - 1)
            if self.strikes[host] >= self.strikes_to_flag:
                flagged.append(host)
        return flagged


@dataclass
class RetryPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.1


def run_supervised(train_loop, ckpt_manager, policy: RetryPolicy
                   ) -> tuple[int, object]:
    """Run ``train_loop(start_step, restored_state) -> (final_step, state)``
    under restart supervision.

    ``train_loop`` raises on simulated/real node failure; supervision
    restores the newest verifiable checkpoint and re-enters.  Returns the
    final (step, state).
    """
    restarts = 0
    while True:
        try:
            return train_loop()
        except Exception:  # noqa: BLE001 — anything fatal triggers restart
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            time.sleep(policy.backoff_s * (2 ** (restarts - 1)))
            # the loop itself re-restores from ckpt_manager on entry
            continue


class Heartbeat:
    """Tiny liveness record used by the elastic controller."""

    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last_seen: dict[str, float] = {}

    def beat(self, host: str, now: float | None = None) -> None:
        self.last_seen[host] = time.time() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[str]:
        now = time.time() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]
