"""Recursive jaxpr/HLO walker: the traversal layer every pass shares.

The old test-private walkers (``tests/test_fused_serving.py::_shapes_in``
and the migration HLO grep in ``tests/test_tiered_runtime.py``) each
re-implemented sub-jaxpr discovery with bespoke handling of nested
``ClosedJaxpr``/``Jaxpr`` leaves, and neither tracked dataflow.  This
module centralizes both:

  * ``collect_eqns``: one pre-order traversal yields EVERY equation of a
    program — through ``pjit``, ``scan``, ``while``, ``cond``,
    ``closed_call``, ``custom_jvp/vjp_call``, ``remat`` and
    ``pallas_call`` — annotated with output/input avals, call-stack path,
    and a raw-KV taint bit;
  * taint: inputs the caller marks as KV sources stay "raw" through
    layout/selection-preserving ops (reshape, gather, scatter, slice,
    convert, select, …) and degrade to "derived" through arithmetic.  A
    dot with a *raw* operand is an attention-read dot (q·k or p·v) — the
    surface whose accumulation dtype the f32 pass checks; a dot whose
    operands are merely derived (e.g. attention output @ w_o) is ordinary
    network compute.  Equations inside ``pallas_call`` kernels carry
    ``in_pallas=True`` instead: ref-mediated dataflow defeats value
    tainting, and every kernel registered here is an attention kernel, so
    passes treat all pallas dots as read-path dots;
  * HLO: ``lower_hlo_text`` compiles a function and returns the optimized
    module text; ``hlo_ops_present`` reports which of a set of op names
    appear in it (the collective-absence pin).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

import jax
from jax.extend import core as jex_core

# Taint lattice: NONE < DERIVED < RAW.  RAW marks values that still *are*
# the KV bytes (possibly re-laid-out / masked); DERIVED marks values merely
# computed from them (scores, probabilities, attention outputs).
TAINT_NONE, TAINT_DERIVED, TAINT_RAW = 0, 1, 2

# Primitives through which RAW taint survives: they move, select or re-type
# the same values without arithmetic that would launder them into "derived".
_TRANSPARENT_PRIMS = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "slice", "dynamic_slice", "dynamic_update_slice", "gather", "scatter",
    "scatter-add", "concatenate", "convert_element_type", "select_n",
    "rev", "pad", "copy", "stop_gradient", "squeeze", "split",
})

# Param keys under which call-like primitives store their sub-jaxpr when the
# eqn invars map 1:1 onto the sub-jaxpr invars (taint can flow exactly).
_ONE_TO_ONE_CALL_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


@dataclass
class WalkedEqn:
    """One equation seen by the recursive traversal."""
    prim: str                      # primitive name
    out_avals: list                # output ShapedArray-likes
    in_avals: list                 # input avals (literals included)
    in_taints: list[int]           # taint level per input
    params: dict                   # raw eqn params
    path: tuple[str, ...]          # call-stack of enclosing primitives
    in_pallas: bool = False        # inside a pallas_call kernel jaxpr
    source: str = ""               # best-effort "file:line" provenance
    cast_f32: bool = False         # dot only: result is immediately
                                   # convert_element_type'd to f32/f64 in
                                   # the same jaxpr (the "explicit cast"
                                   # accumulation idiom)


def _aval(v):
    return getattr(v, "aval", None)


def _source_of(eqn) -> str:
    info = getattr(eqn, "source_info", None)
    try:
        frame = jax.api_util.user_frame(info.traceback) \
            if info is not None and info.traceback is not None else None
    except Exception:
        frame = None
    if frame is None:
        return ""
    return f"{frame.file_name}:{frame.start_line}"


def _sub_jaxprs_generic(params: dict):
    """Every (key, ClosedJaxpr|Jaxpr) nested anywhere in eqn params — the
    uniform discovery the old per-test walkers botched case by case."""
    for key, val in params.items():
        for sub in jax.tree_util.tree_leaves(
                val, is_leaf=lambda x: isinstance(
                    x, (jex_core.Jaxpr, jex_core.ClosedJaxpr))):
            if isinstance(sub, (jex_core.Jaxpr, jex_core.ClosedJaxpr)):
                yield key, sub


def _as_jaxpr(sub) -> jex_core.Jaxpr:
    return sub.jaxpr if isinstance(sub, jex_core.ClosedJaxpr) else sub


def _call_taint_map(eqn, sub: jex_core.Jaxpr,
                    taint_of: Callable[[Any], int]) -> dict:
    """Map caller-side taint onto a sub-jaxpr's invars.

    Exact mappings for the structured control-flow primitives; for anything
    else, a positional map when lengths line up, else RAW/DERIVED collapse
    onto every sub invar (conservative: never silently drops taint)."""
    name = eqn.primitive.name
    in_t = [taint_of(v) for v in eqn.invars]
    sub_in = list(sub.invars)
    if name == "while":
        # invars = cond_consts + body_consts + carry;
        # body invars = body_consts + carry; cond invars = cond_consts+carry
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        body = _as_jaxpr(eqn.params["body_jaxpr"])
        if sub is body:
            src = in_t[cn:]
        else:
            src = in_t[:cn] + in_t[cn + bn:]
        if len(src) == len(sub_in):
            return dict(zip(sub_in, src))
    elif name == "cond":
        src = in_t[1:]                       # invars[0] is the branch index
        if len(src) == len(sub_in):
            return dict(zip(sub_in, src))
    elif len(in_t) == len(sub_in):
        # pjit / scan / closed_call / custom_* : 1:1 by construction
        # (scan: consts + carry + xs in both frames)
        return dict(zip(sub_in, in_t))
    elif len(in_t) <= len(sub_in):
        # pallas_call: invars map onto the leading input refs; the trailing
        # output/scratch refs start untainted
        m = dict(zip(sub_in, in_t + [TAINT_NONE] * (len(sub_in) - len(in_t))))
        return m
    worst = max(in_t, default=TAINT_NONE)
    return {v: worst for v in sub_in}


def collect_eqns(jaxpr, kv_invars: Iterable[int] = (),
                 const_taints: dict | None = None) -> list[WalkedEqn]:
    """Walk a (Closed)Jaxpr recursively, returning every equation.

    kv_invars: indices of the top-level invars that are raw KV sources
    (pool/near/far K,V buffers).  Taint propagates through every nesting
    level; see the module docstring for the lattice.
    """
    if isinstance(jaxpr, jex_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out: list[WalkedEqn] = []
    kv = set(kv_invars)
    taints: dict = dict(const_taints or {})
    for i, v in enumerate(jaxpr.invars):
        taints[v] = TAINT_RAW if i in kv else taints.get(v, TAINT_NONE)

    def run(jx: jex_core.Jaxpr, taints: dict, path: tuple[str, ...],
            in_pallas: bool):
        def taint_of(v):
            if isinstance(v, jex_core.Literal):
                return TAINT_NONE
            return taints.get(v, TAINT_NONE)

        dot_of_var: dict = {}      # dot outvar -> its WalkedEqn record
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name == "convert_element_type" and \
                    str(eqn.params.get("new_dtype")) in ("float32",
                                                         "float64"):
                for v in eqn.invars:
                    rec = dot_of_var.get(id(v))
                    if rec is not None:
                        rec.cast_f32 = True
            in_t = [taint_of(v) for v in eqn.invars]
            out.append(WalkedEqn(
                prim=name,
                out_avals=[_aval(v) for v in eqn.outvars],
                in_avals=[_aval(v) for v in eqn.invars],
                in_taints=in_t,
                params=eqn.params,
                path=path,
                in_pallas=in_pallas,
                source=_source_of(eqn)))
            if name == "dot_general":
                for v in eqn.outvars:
                    dot_of_var[id(v)] = out[-1]
            elif name in _TRANSPARENT_PRIMS:
                # a dot's "explicit f32 cast" may sit behind a transpose /
                # reshape the einsum inserted — carry the dot record along
                recs = [dot_of_var[id(v)] for v in eqn.invars
                        if not isinstance(v, jex_core.Literal)
                        and id(v) in dot_of_var]
                if recs:
                    for v in eqn.outvars:
                        dot_of_var[id(v)] = recs[0]
            subs = list(_sub_jaxprs_generic(eqn.params))
            sub_out_taints: list[list[int]] = []
            for _, sub in subs:
                sub_j = _as_jaxpr(sub)
                sub_taints = _call_taint_map(eqn, sub_j, taint_of)
                run(sub_j, sub_taints, path + (name,),
                    in_pallas or name == "pallas_call")
                sub_out_taints.append(
                    [TAINT_NONE if isinstance(v, jex_core.Literal)
                     else sub_taints.get(v, TAINT_NONE)
                     for v in sub_j.outvars])
            # output taint: transparent prims keep RAW alive; call-like
            # prims read it back from their sub-jaxpr's outvars (1:1 for
            # pjit/scan/closed_call/custom_*; cond takes the max across
            # branches; the while body's carry IS the eqn output); other
            # arithmetic degrades the max input taint to DERIVED
            exact = [ts for ts in sub_out_taints
                     if len(ts) == len(eqn.outvars)]
            worst = max(in_t, default=TAINT_NONE)
            if name in _TRANSPARENT_PRIMS:
                per_out = [worst] * len(eqn.outvars)
            elif exact:
                per_out = [max(ts[i] for ts in exact)
                           for i in range(len(eqn.outvars))]
            elif worst == TAINT_NONE:
                per_out = [TAINT_NONE] * len(eqn.outvars)
            else:
                per_out = [TAINT_DERIVED] * len(eqn.outvars)
            for v, o in zip(eqn.outvars, per_out):
                taints[v] = o

    run(jaxpr, taints, (), False)
    return out


def intermediate_shapes(jaxpr) -> set[tuple]:
    """Every output shape of every equation, at every nesting depth — the
    drop-in replacement for the old ``_shapes_in`` test helper."""
    shapes: set[tuple] = set()
    for we in collect_eqns(jaxpr):
        for a in we.out_avals:
            if a is not None and hasattr(a, "shape"):
                shapes.add(tuple(a.shape))
    return shapes


def dots(walked: list[WalkedEqn]) -> list[WalkedEqn]:
    """The dot/convolution equations of a walked program."""
    return [we for we in walked
            if we.prim in ("dot_general", "conv_general_dilated")]


def kv_invar_indices(example_args, is_kv_path) -> list[int]:
    """Flatten example args and return the flat indices whose tree path
    satisfies ``is_kv_path`` (a predicate on the jax keypath string) —
    exactly the invar order ``jax.make_jaxpr`` produces."""
    leaves = jax.tree_util.tree_leaves_with_path(example_args)
    idx = []
    for i, (path, _) in enumerate(leaves):
        if is_kv_path(jax.tree_util.keystr(path)):
            idx.append(i)
    return idx


# -- HLO ---------------------------------------------------------------------

def lower_hlo_text(fn, *args, **kwargs) -> str:
    """Compile ``fn(*args)`` and return the optimized HLO module text."""
    return jax.jit(fn, **kwargs).lower(*args).compile().as_text()


def hlo_ops_present(hlo_text: str, ops: Iterable[str]) -> list[str]:
    """Which of ``ops`` (HLO op names, e.g. "all-reduce") appear as
    instructions in the module text.  Matches on " opname(" after the "="
    to avoid false hits in metadata strings."""
    present = []
    for op in ops:
        needle = f" {op}("
        if any(needle in line and "=" in line.split(needle)[0]
               for line in hlo_text.splitlines()):
            present.append(op)
    return present


COLLECTIVE_OPS = ("all-reduce", "all-gather", "all-to-all",
                  "collective-permute", "reduce-scatter")

# jaxpr-level collective primitives -> the HLO op kind each lowers to.
# Unlike optimized HLO (where axis names are erased into replica groups),
# jaxpr collectives still carry their mesh axis names in eqn params — the
# layer where "a psum over the declared 'model' axis" is checkable at all.
COLLECTIVE_PRIMS = {
    "psum": "all-reduce",
    "pmax": "all-reduce",
    "pmin": "all-reduce",
    "all_gather": "all-gather",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
    "pshuffle": "collective-permute",
    "psum_scatter": "reduce-scatter",
}


def collective_axes(we: WalkedEqn) -> tuple[str, ...]:
    """The mesh axis names a jaxpr collective equation reduces/gathers
    over.  psum-family primitives store them under ``axes``; the
    gather/permute family under ``axis_name``; either may be a single name
    or a tuple."""
    axes = we.params.get("axes", we.params.get("axis_name", ()))
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    return tuple(str(a) for a in axes)


def jaxpr_collectives(walked: list[WalkedEqn]) \
        -> list[tuple[WalkedEqn, tuple[str, ...]]]:
    """Every collective equation of a walked program (at any nesting depth
    — ``collect_eqns`` recurses through ``shard_map`` like any other
    call-like primitive) with its axis names."""
    return [(we, collective_axes(we)) for we in walked
            if we.prim in COLLECTIVE_PRIMS]
