"""Qwen2-VL-2B: vision-language decoder backbone with M-RoPE.

[arXiv:2409.12191; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
The vision frontend (dynamic-resolution ViT) is a stub: ``input_specs()``
provides precomputed patch embeddings, per the assignment brief.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151_936,
    mrope=True,
    frontend="vision",
    tie_embeddings=True,
    source="arXiv:2409.12191; hf",
)
