"""Elastic scaling: rebuild the mesh from surviving devices and reshard.

When hosts die (heartbeat timeout) or join, the controller:
  1. picks the largest supported mesh shape <= surviving device count;
  2. rebuilds shardings from the same rule set (`sharding.specs`) — the rules
     are mesh-parametric, so no per-topology code;
  3. reshards the restored checkpoint onto the new mesh (`jax.device_put`
     with the new NamedShardings; arrays were host-gathered by restore);
  4. rescales the data-parallel batch (global batch preserved by gradient
     accumulation when the DP width shrank).

``plan_mesh`` is pure and fully unit-testable; ``reshard`` works on any
device set (tests exercise it on CPU devices).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.sharding import specs as sh


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dp_size: int
    grad_accum: int              # restores the global batch


def plan_mesh(n_devices: int, model_parallel: int,
              target_dp: int) -> ElasticPlan:
    """Largest (data, model) mesh fitting n_devices with the given TP width.

    Model parallelism is preserved (resharding TP mid-run would change
    per-op layouts); data parallelism absorbs the loss, with gradient
    accumulation keeping the global batch constant.
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"need >= {model_parallel} devices for model parallelism, "
            f"have {n_devices}")
    dp = n_devices // model_parallel
    # dp must divide the target so accumulation is integral
    while dp > 1 and target_dp % dp:
        dp -= 1
    accum = target_dp // dp
    return ElasticPlan(mesh_shape=(dp, model_parallel),
                       axis_names=("data", "model"),
                       dp_size=dp, grad_accum=accum)


def build_mesh(plan: ElasticPlan, devices=None):
    devices = np.asarray(devices if devices is not None else jax.devices())
    need = int(np.prod(plan.mesh_shape))
    return jax.sharding.Mesh(
        devices[:need].reshape(plan.mesh_shape), plan.axis_names)


def reshard(tree, arch, mesh, fsdp: bool = True):
    """Place a host-resident pytree onto ``mesh`` under the standard rules."""
    pspecs = sh.param_specs(tree, arch, mesh, fsdp=fsdp)
    shardings = sh.to_named(pspecs, mesh)
    return jax.tree.map(jax.device_put, tree, shardings)
