"""Pallas flash-decode kernel that walks the page table INSIDE the kernel.

The paged far tier's read path used to materialize every slot's full far
view — a `(B, n_pages*page, Hkv, hd)` gather per decode step per layer —
before attending it, touching `n_pages*page` rows per slot regardless of how
few pages were actually live.  TL-DRAM's far segment is accessed *in place*
through the isolation transistor: cost is paid per access, never per bit of
the segment (PAPER.md §3).  This kernel applies that economics to the
gather path itself:

  grid (B, Hkv); per step the kernel
    1. attends the shared NEAR buffer (VMEM-resident, `C` page panels)
       under per-(slot, near-slot) live counts — the global near tier
       serves every tenant of a promoted page with its own position mask;
    2. walks the slot's compacted page-table WALK LIST with a
       `fori_loop`, issuing ONE async pool->VMEM copy per *mapped,
       non-promoted, live* page and online-softmaxing the page panel
       under its partial-last-page live count.

  Far bytes touched per step per slot == sum of live, non-promoted page
  rows — never `n_pages * page` (asserted end-to-end by the serving
  accounting in BENCH_serving.json).

The walk list / near metadata (`core.tiered_kv.paged_step_metadata`) is a
handful of small int arrays computed ONCE per decode step from
`(page_table, slot_of_page, page_of_slot, lengths)` and passed to every
layer — it rides in SMEM; nothing `(B, n_pages, C)`-shaped exists anywhere
on the per-layer path.

The pool lives in `ANY` memory (HBM): only the walked pages transit VMEM,
via a per-page DMA into a `(page, hd)` scratch panel.  Production note: a
double-buffered two-panel pipeline would hide the copy latency behind the
panel matmul; the single-panel form keeps the walk logic auditable and is
what the interpret-mode suite validates.

Returns *unnormalized* `(out, m, l)` online-softmax stats, the same
contract as `kernels.tiered_attention`, so callers can LSE-merge with other
partial results exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def paged_attention_stats(q: jax.Array, pool_k: jax.Array,
                          pool_v: jax.Array, near_k: jax.Array,
                          near_v: jax.Array, meta: dict, mesh=None):
    """Run the fused kernel from a ``paged_step_metadata`` dict — the one
    entry point both the serving decode step and the core read path /
    verification probe share (interpret mode on CPU backends).

    With a ``mesh`` whose 'model' axis divides Hkv, the pool and near
    buffers are KV-HEAD-SHARDED and the kernel runs under ``shard_map``:
    each device walks only its head slice of every mapped page (GSPMD
    cannot partition a ``pallas_call``, so the shard boundary is explicit).
    The per-head math is untouched — the kernel's grid is ``(B, Hkv)`` and
    no arithmetic crosses heads — so a tiled ``all_gather`` of the per-head
    stats over 'model' returns REPLICATED (out, m, l) bit-identical to the
    single-device call, and every cross-head reduction downstream (the wo
    projection, the LSE merge) sees the full head dim in single-device
    order.  Head counts that do not divide the axis fall back to the
    replicated single-device call (``kv_shard_count``)."""
    from repro.sharding.specs import kv_shard_count
    interpret = jax.default_backend() == "cpu"
    Hkv = pool_k.shape[-2]
    if kv_shard_count(mesh, Hkv) == 1:
        return paged_attention(q, pool_k, pool_v, near_k, near_v,
                               meta["walk_pid"], meta["walk_live"],
                               meta["walk_len"], meta["near_live"],
                               interpret=interpret)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    B, H, hd = q.shape
    g = H // Hkv

    def local_stats(q4, pk, pv, nk, nv, w_pid, w_live, w_len, n_live):
        Hl = pk.shape[-2]                       # this shard's kv heads
        out, m, l = paged_attention(q4.reshape(B, Hl * g, hd), pk, pv,
                                    nk, nv, w_pid, w_live, w_len, n_live,
                                    interpret=interpret)
        gather = functools.partial(jax.lax.all_gather, axis_name="model",
                                   axis=1, tiled=True)
        return (gather(out.reshape(B, Hl, g, hd)).reshape(B, H, hd),
                gather(m.reshape(B, Hl, g)).reshape(B, H),
                gather(l.reshape(B, Hl, g)).reshape(B, H))

    head = P(None, "model")                     # dim ndim-2 = Hkv
    sharded = shard_map(
        local_stats, mesh=mesh,
        in_specs=(P(None, "model"), P(None, None, "model"),
                  P(None, None, "model"), head, head,
                  P(), P(), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False)                        # pallas body: no rep tracking
    return sharded(q.reshape(B, Hkv, g, hd), pool_k, pool_v, near_k, near_v,
                   meta["walk_pid"], meta["walk_live"], meta["walk_len"],
                   meta["near_live"])


def _paged_attention_kernel(h_ref, walk_pid_ref, walk_live_ref, walk_len_ref,
                            near_live_ref, q_ref, nk_ref, nv_ref,
                            pool_k_ref, pool_v_ref,
                            o_ref, m_ref, l_ref,
                            kbuf, vbuf, sem_k, sem_v, *,
                            page: int, n_near: int, scale: float):
    h = h_ref[0]                        # this grid step's KV head (SMEM iota:
                                        # interpret mode lacks program_id)
    q = q_ref[0, 0].astype(jnp.float32) * scale              # (g, hd)
    g, hd = q.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)

    def update(carry, kp, vp, live):
        """One page panel's online-softmax update; rows >= live are dead."""
        acc, m, l = carry
        s = jax.lax.dot_general(q, kp, (((1,), (1,)), ((), ())))  # (g, page)
        alive = row < live
        s = jnp.where(alive, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(alive, p, 0.0)
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p, vp, (((1,), (0,)), ((), ())))
        return acc_new, m_new, l_new

    acc = jnp.zeros((g, hd), jnp.float32)
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)

    # -- near pass: C resident panels, dense in VMEM --------------------------
    def near_body(c, carry):
        kp = nk_ref[pl.ds(c * page, page), 0, :].astype(jnp.float32)
        vp = nv_ref[pl.ds(c * page, page), 0, :].astype(jnp.float32)
        return update(carry, kp, vp, near_live_ref[0, c])

    acc, m, l = jax.lax.fori_loop(0, n_near, near_body, (acc, m, l))

    # -- far pass: walk the slot's live, non-promoted pages -------------------
    def far_body(i, carry):
        pid = walk_pid_ref[0, i]
        cp_k = pltpu.make_async_copy(pool_k_ref.at[pid, :, h], kbuf, sem_k)
        cp_v = pltpu.make_async_copy(pool_v_ref.at[pid, :, h], vbuf, sem_v)
        cp_k.start()
        cp_v.start()
        cp_k.wait()
        cp_v.wait()
        return update(carry, kbuf[...].astype(jnp.float32),
                      vbuf[...].astype(jnp.float32), walk_live_ref[0, i])

    acc, m, l = jax.lax.fori_loop(0, walk_len_ref[0], far_body, (acc, m, l))

    o_ref[0, 0] = acc
    m_ref[0, 0] = m[:, 0]
    l_ref[0, 0] = l[:, 0]


def paged_attention(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                    near_k: jax.Array, near_v: jax.Array,
                    walk_pid: jax.Array, walk_live: jax.Array,
                    walk_len: jax.Array, near_live: jax.Array,
                    interpret: bool = False):
    """Fused two-tier paged decode attention.

    q: (B, H, hd) single-token queries (GQA: H a multiple of Hkv).
    pool_k/pool_v: (P, page, Hkv, hd) shared far pool (stays in HBM/ANY).
    near_k/near_v: (C*page, Hkv, hd) global near buffer (VMEM-streamed).
    walk_pid/walk_live: (B, W) int32 — per slot, the pool ids of its mapped,
      non-promoted, live pages (front-packed) and each page's live row
      count (partial-last-page mask); entries past ``walk_len[b]`` unused.
    walk_len: (B,) int32.  near_live: (B, C) int32 — per (slot, near-slot)
      live rows (0 masks the whole panel, serving non-tenants and empties).

    Returns (out (B,H,hd) f32 unnormalized, m (B,H) f32, l (B,H) f32).
    """
    B, H, hd = q.shape
    P, page, Hkv, _ = pool_k.shape
    g = H // Hkv
    n_near = near_k.shape[0] // page
    W = walk_pid.shape[1]
    q4 = q.reshape(B, Hkv, g, hd)
    heads = jnp.arange(Hkv, dtype=jnp.int32)
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)

    kernel = functools.partial(_paged_attention_kernel, page=page,
                               n_near=n_near, scale=hd ** -0.5)
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    out, m, l = pl.pallas_call(
        kernel,
        grid=(B, Hkv),
        in_specs=[
            smem((1,), lambda b, h: (h,)),
            smem((1, W), lambda b, h: (b, 0)),
            smem((1, W), lambda b, h: (b, 0)),
            smem((1,), lambda b, h: (b,)),
            smem((1, n_near), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1, g, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((n_near * page, 1, hd), lambda b, h: (0, h, 0)),
            pl.BlockSpec((n_near * page, 1, hd), lambda b, h: (0, h, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((page, hd), pool_k.dtype),
            pltpu.VMEM((page, hd), pool_v.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(heads, i32(walk_pid), i32(walk_live), i32(walk_len), i32(near_live),
      q4, near_k, near_v, pool_k, pool_v)
    return (out.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H))
