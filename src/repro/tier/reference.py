"""Reference object-model policies — the parity oracle (docs/tier.md §Oracle).

This is the original per-subarray dict/object implementation of the paper's
near-segment policies (SC / WMC / BBC / STATIC).  It is no longer on any hot
path: the DRAM simulator drives the vectorized `repro.tier.engine` and the
TPU runtime drives `repro.tier.jax_engine`.  It is kept verbatim as the
readable specification of the per-access semantics, and as the oracle that
``tests/test_tier_parity.py`` replays access streams against to prove the
vectorized engines make identical decisions.

``PolicyCosts`` is now an alias of the unified `repro.tier.costs.TierCosts`.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.tier.costs import TierCosts

PolicyCosts = TierCosts


@dataclass
class CacheState:
    """Near-segment cache state for one subarray (or one tier group)."""

    capacity: int
    # slot -> cached far row id (dense list, None = empty slot)
    slots: list[int | None] = field(default_factory=list)
    # far row id -> slot
    lookup: dict[int, int] = field(default_factory=dict)
    dirty: set[int] = field(default_factory=set)        # far row ids
    last_use: dict[int, float] = field(default_factory=dict)   # row -> time
    score: dict[int, float] = field(default_factory=dict)      # row -> decayed freq

    def __post_init__(self):
        if not self.slots:
            self.slots = [None] * self.capacity

    def hit(self, row: int) -> bool:
        return row in self.lookup

    def occupancy(self) -> int:
        return len(self.lookup)


@dataclass
class Decision:
    """What the controller should do after serving an access."""

    promote: bool = False
    victim_row: int | None = None     # far row to evict (None if empty slot)
    victim_dirty: bool = False        # eviction needs a write-back IST
    slot: int | None = None


class Policy:
    """Base class; subclasses implement ``decide``."""

    name = "base"

    def __init__(self, costs: PolicyCosts, decay: float | None = None):
        self.costs = costs
        # Single source of truth: the unified TierCosts carries the decay
        # the vectorized engines use; an explicit argument still overrides.
        self.decay = costs.decay if decay is None else decay

    # -- bookkeeping shared by all policies --------------------------------

    def on_access(self, st: CacheState, row: int, now: float,
                  is_write: bool, in_near: bool,
                  activated: bool = True) -> None:
        st.last_use[row] = now
        # The near segment saves latency/energy per ACTIVATION, not per column
        # access: row-buffer hits are free either way.  Score activations only.
        if activated:
            st.score[row] = st.score.get(row, 0.0) + 1.0
        if in_near and is_write:
            st.dirty.add(row)

    def decay_scores(self, st: CacheState) -> None:
        for k in list(st.score):
            st.score[k] *= self.decay
            if st.score[k] < 1e-3:
                del st.score[k]

    def apply_promotion(self, st: CacheState, row: int, d: Decision) -> None:
        if d.victim_row is not None:
            slot = st.lookup.pop(d.victim_row)
            st.dirty.discard(d.victim_row)
        else:
            slot = d.slot if d.slot is not None else st.slots.index(None)
        st.slots[slot] = row
        st.lookup[row] = slot

    # -- policy decision -----------------------------------------------------

    def decide(self, st: CacheState, row: int, now: float,
               bank_idle: bool) -> Decision:
        raise NotImplementedError

    # -- helpers ------------------------------------------------------------

    def _lru_victim(self, st: CacheState) -> tuple[int | None, int | None]:
        """Returns (victim_row, slot). victim_row None => an empty slot exists."""
        if st.occupancy() < st.capacity:
            return None, st.slots.index(None)
        victim = min(st.lookup, key=lambda r: st.last_use.get(r, 0.0))
        return victim, st.lookup[victim]

    def _min_benefit_victim(self, st: CacheState) -> tuple[int | None, int | None]:
        if st.occupancy() < st.capacity:
            return None, st.slots.index(None)
        victim = min(st.lookup, key=lambda r: st.score.get(r, 0.0))
        return victim, st.lookup[victim]


class SimpleCaching(Policy):
    """SC: cache every far-row access (LRU)."""

    name = "SC"

    def decide(self, st, row, now, bank_idle):
        victim, slot = self._lru_victim(st)
        return Decision(promote=True, victim_row=victim,
                        victim_dirty=victim in st.dirty if victim is not None else False,
                        slot=slot)


class WaitMinimizedCaching(Policy):
    """WMC: cache only when the migration cannot delay pending requests."""

    name = "WMC"

    def decide(self, st, row, now, bank_idle):
        if not bank_idle:
            return Decision(promote=False)
        victim, slot = self._lru_victim(st)
        return Decision(promote=True, victim_row=victim,
                        victim_dirty=victim in st.dirty if victim is not None else False,
                        slot=slot)


class BenefitBasedCaching(Policy):
    """BBC: promote when expected benefit exceeds victim benefit + cost.

    benefit(row) = decayed_access_frequency(row) * saving_per_access
    promote iff benefit(candidate) > benefit(victim) + migrate_cost_amortized
    """

    name = "BBC"

    def __init__(self, costs: PolicyCosts, decay: float | None = None,
                 hysteresis: float | None = None,
                 min_score: float | None = None):
        super().__init__(costs, decay)
        self.hysteresis = costs.hysteresis if hysteresis is None else hysteresis
        # A row must show *sustained* reuse (several decayed activations)
        # before it is worth a migration: one or two activations predict
        # nothing under streaming/uniform traffic (paper samples activation
        # counts per interval for the same reason).
        self.min_score = costs.min_score if min_score is None else min_score

    def decide(self, st, row, now, bank_idle):
        score = st.score.get(row, 0.0)
        if score < self.min_score:
            return Decision(promote=False)
        cand_benefit = score * self.costs.saving_per_access
        victim, slot = self._min_benefit_victim(st)
        if victim is None:
            # Empty slot: promote if the row simply pays for its migration.
            if cand_benefit > self.costs.migrate_cost:
                return Decision(promote=True, victim_row=None, slot=slot)
            return Decision(promote=False)
        victim_benefit = st.score.get(victim, 0.0) * self.costs.saving_per_access
        extra = self.costs.migrate_cost * (2.0 if victim in st.dirty else 1.0)
        if cand_benefit > victim_benefit + extra * self.hysteresis:
            return Decision(promote=True, victim_row=victim,
                            victim_dirty=victim in st.dirty, slot=slot)
        return Decision(promote=False)


class StaticProfile(Policy):
    """OS-exposed mechanism: hottest rows placed at t=0, no runtime migration.

    ``preload`` must be called with profiled per-row access counts before the
    run (the OS's static/dynamic profiling step in the paper).
    """

    name = "STATIC"

    def preload(self, st: CacheState, row_counts: dict[int, int]) -> None:
        hottest = sorted(row_counts, key=row_counts.get, reverse=True)
        for slot, row in enumerate(hottest[: st.capacity]):
            st.slots[slot] = row
            st.lookup[row] = slot

    def decide(self, st, row, now, bank_idle):
        return Decision(promote=False)


POLICIES: dict[str, type[Policy]] = {
    "SC": SimpleCaching,
    "WMC": WaitMinimizedCaching,
    "BBC": BenefitBasedCaching,
    "STATIC": StaticProfile,
}


def make_policy(name: str, costs: PolicyCosts, **kw) -> Policy:
    return POLICIES[name.upper()](costs, **kw)
