"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python for correctness validation; on TPU the same code
lowers to Mosaic.  ``tiered_decode_attention`` composes the near-tier Pallas
kernel with the far-tier XLA path and the exact log-sum-exp merge — the
two-tier read path of the TL-DRAM adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ssd_scan import ssd_chunk_scan
from repro.kernels.tiered_attention import near_decode_attention
from repro.kernels.tiered_gather import tiered_gather


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_t",))
def tiered_embedding_gather(near_table, near_slots, far_values,
                            block_t: int = 256):
    return tiered_gather(near_table, near_slots, far_values, block_t=block_t,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_h",))
def ssd_state_scan(states, decays, h0, block_h: int = 8):
    return ssd_chunk_scan(states, decays, h0, block_h=block_h,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_kv",))
def tiered_decode_attention(q, k_near, v_near, near_len,
                            k_far, v_far, far_len, block_kv: int = 128):
    """Two-tier decode attention (the TL-DRAM read path).

    q: (B,H,hd).  Near tier: contiguous (B,T_near,Hkv,hd) + live count —
    attended by the Pallas kernel (fast path).  Far tier: (B,T_far,Hkv,hd)
    + live count — attended by the XLA path (slow path).  Exact LSE merge.
    """
    near = near_decode_attention(q, k_near, v_near, near_len,
                                 block_kv=block_kv, interpret=_interpret())
    far = ref.decode_attention_stats_ref(q[:, None], k_far, v_far, far_len)
    return ref.merge_attention_stats([near, far])
