"""Deterministic, resumable, sharded synthetic LM data pipeline.

Production properties the trainer depends on:
  * deterministic: batch i is a pure function of (seed, step) — any worker
    can recompute any batch (no data-loss on restart);
  * resumable: the iterator state is just the step counter, checkpointed
    alongside params;
  * sharded: each data-parallel rank materializes only its slice
    (host-sharded loading; the dry-run feeds global ShapeDtypeStructs).

The token stream is a mixture of Zipfian unigrams with Markov bigram
structure, so losses actually *decrease* under training (unlike uniform
noise) and the tiered-embedding near tier sees realistic skew.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import model_zoo


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_alpha: float = 1.1
    bigram_weight: float = 0.5    # how much of the next-token dist is bigram


class SyntheticLM:
    """Batch i == f(seed, i); shard-aware."""

    def __init__(self, arch: ArchConfig, shape: InputShape,
                 cfg: DataConfig = DataConfig(),
                 rank: int = 0, world: int = 1):
        self.arch = arch
        self.shape = shape
        self.cfg = cfg
        self.rank = rank
        self.world = world
        assert shape.global_batch % world == 0
        self.local_batch = shape.global_batch // world
        v = arch.vocab
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = ranks ** (-cfg.zipf_alpha)
        self.unigram /= self.unigram.sum()
        # sparse "bigram" structure: each token has a preferred successor
        self.successor = rng.permutation(v)

    def batch(self, step: int) -> dict:
        """Materialize this rank's slice of global batch ``step``."""
        B, S = self.local_batch, self.shape.seq_len
        out_tokens = np.empty((B, S + 1), np.int32)
        for b in range(B):
            global_idx = step * self.shape.global_batch \
                + self.rank * B + b
            rng = np.random.default_rng(
                np.random.SeedSequence([self.cfg.seed, global_idx]))
            toks = rng.choice(self.arch.vocab, size=S + 1, p=self.unigram)
            # inject bigram transitions
            follow = rng.random(S) < self.cfg.bigram_weight
            nxt = self.successor[toks[:-1]]
            toks[1:] = np.where(follow, nxt, toks[1:])
            out_tokens[b] = toks
        batch = {"tokens": out_tokens[:, :-1],
                 "labels": out_tokens[:, 1:].astype(np.int32)}
        if self.arch.family == "vlm":
            n_patch = model_zoo.n_patches(S)
            rng = np.random.default_rng(
                np.random.SeedSequence([self.cfg.seed, 7, step, self.rank]))
            batch["patch_embeds"] = rng.standard_normal(
                (B, n_patch, self.arch.d_model)).astype(np.float32) * 0.02
            pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, :, None],
                                  (B, S, 3))
            batch["positions"] = np.ascontiguousarray(pos)
        if self.arch.family == "audio":
            rng = np.random.default_rng(
                np.random.SeedSequence([self.cfg.seed, 8, step, self.rank]))
            batch["frame_embeds"] = rng.standard_normal(
                (B, S, self.arch.d_model)).astype(np.float32) * 0.02
            batch["labels"] = rng.integers(
                0, self.arch.vocab, size=(B, S, self.arch.n_codebooks),
                dtype=np.int32)
            del batch["tokens"]
        return batch

    # -- iterator protocol with explicit, checkpointable state ---------------

    def state(self, step: int) -> dict:
        return {"step": step, "seed": self.cfg.seed,
                "rank": self.rank, "world": self.world}

    @staticmethod
    def restore_step(state: dict) -> int:
        return int(state["step"])
