"""One-shot calibration of the TL-DRAM timing model against the paper.

Two stages (see the "Calibration layer" note in ``tldram.py``):

1. Fit the affine map ``t_cal = a + b * t_ode`` per timing constraint from the
   two unsegmented anchor designs (short-32, long-512), using Table 1 of the
   paper for tRC and JEDEC DDR3 / RLDRAM-class values for tRCD and tRP.
2. Bisect the isolation-transistor resistance ``r_iso`` so the *calibrated*
   far-480 tRC reproduces Table 1's 65.8 ns.

Run ``python -m repro.core.calibrate`` to regenerate the constants baked into
``tldram.DEFAULT_CAL`` / ``CircuitParams.r_iso_ohm``.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import tldram


def fit_affine(x0: float, y0: float, x1: float, y1: float) -> tuple[float, float]:
    b = (y1 - y0) / (x1 - x0)
    return y0 - b * x0, b


def calibrate(verbose: bool = True) -> tuple[tldram.AffineCal, tldram.CircuitParams]:
    p = tldram.CircuitParams()
    short = tldram.timings("unsegmented", tldram.TABLE1_NEAR_CELLS, params=p)
    long_ = tldram.timings("unsegmented", tldram.CELLS_PER_BITLINE, params=p)

    a_rc, b_rc = fit_affine(short.t_rc, tldram.TABLE1_TRC_NS["short_32"],
                            long_.t_rc, tldram.TABLE1_TRC_NS["long_512"])
    a_rcd, b_rcd = fit_affine(short.t_rcd, tldram.TRCD_ANCHORS_NS["short_32"],
                              long_.t_rcd, tldram.TRCD_ANCHORS_NS["long_512"])
    a_rp, b_rp = fit_affine(short.t_rp, tldram.TRP_ANCHORS_NS["short_32"],
                            long_.t_rp, tldram.TRP_ANCHORS_NS["long_512"])
    cal = tldram.AffineCal(a_rcd=a_rcd, b_rcd=b_rcd, a_rc=a_rc, b_rc=b_rc,
                           a_rp=a_rp, b_rp=b_rp)

    # Solve r_iso so calibrated far-480 tRC = 65.8 ns (monotone increasing).
    target = tldram.TABLE1_TRC_NS["far_480"]

    def far_trc(r_iso: float) -> float:
        q = dataclasses.replace(p, r_iso_ohm=r_iso)
        return tldram.calibrated_timings(
            "far", tldram.TABLE1_FAR_CELLS, tldram.TABLE1_NEAR_CELLS,
            params=q, cal=cal).t_rc

    lo, hi = math.log(10.0), math.log(10e6)
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if far_trc(math.exp(mid)) > target:
            hi = mid
        else:
            lo = mid
    p = dataclasses.replace(p, r_iso_ohm=math.exp(0.5 * (lo + hi)))

    if verbose:
        print(f"AffineCal(a_rcd={cal.a_rcd:.6f}, b_rcd={cal.b_rcd:.6f}, "
              f"a_rc={cal.a_rc:.6f}, b_rc={cal.b_rc:.6f}, "
              f"a_rp={cal.a_rp:.6f}, b_rp={cal.b_rp:.6f})")
        print(f"r_iso_ohm = {p.r_iso_ohm:.3f}")
        for name, t in tldram.table1_model(p, cal=cal, calibrated=True).items():
            print(f"{name:10s} tRCD={t.t_rcd:6.2f}  tRAS={t.t_ras:6.2f}  "
                  f"tRP={t.t_rp:6.2f}  tRC={t.t_rc:6.2f}  "
                  f"(target tRC {tldram.TABLE1_TRC_NS[name]})")
    return cal, p


if __name__ == "__main__":
    calibrate()
