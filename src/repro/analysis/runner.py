"""Drive every invariant pass over every registered target + the ownership
linter, apply the committed baseline, and produce an ``AnalysisReport``."""

from __future__ import annotations

from pathlib import Path

from repro.analysis.report import (AnalysisReport, load_allowed_axes,
                                   load_baseline)

# src/repro — the tree the ownership linter audits.
DEFAULT_SRC_ROOT = Path(__file__).resolve().parents[1]
# repo root — where the committed baseline lives.
DEFAULT_BASELINE = Path(__file__).resolve().parents[3] / \
    "analysis_baseline.json"


def run_analysis(mode: str | None = None,
                 src_root: str | Path | None = None,
                 baseline: str | Path | dict | None = None,
                 targets=None,
                 with_ownership: bool = True,
                 allowed_axes: dict | None = None) -> AnalysisReport:
    """One full analysis run under one kernel mode.

    mode: dense | gather | fused (default: $REPRO_KERNEL_MODE).
    baseline: a waiver dict, a path to the baseline JSON, or None for the
    committed ``analysis_baseline.json`` at the repo root.
    targets: override the registry (tests plant broken mini-steps here).
    allowed_axes: per-target declared mesh axes for the no-collectives
    pass ({target name: [axis, ...]}); None reads the baseline file's
    ``allowed_axes`` section (or {} when the baseline is an in-memory
    waiver dict).  Merged into each target by name.
    """
    from repro.analysis import passes as passes_mod
    from repro.analysis import targets as targets_mod
    from repro.analysis.ownership import lint_ownership

    mode = mode or targets_mod.kernel_mode()
    if targets is None:
        targets = targets_mod.build_targets(mode)
    if allowed_axes is None:
        src = DEFAULT_BASELINE if baseline is None else baseline
        allowed_axes = {} if isinstance(src, dict) else load_allowed_axes(src)
    for t in targets:
        extra = allowed_axes.get(t.name, ())
        if extra:
            t.allowed_axes = tuple(dict.fromkeys(
                (*t.allowed_axes, *extra)))

    report = AnalysisReport(kernel_mode=mode)
    for p in passes_mod.PASSES:
        report.passes_run.append(p.name)
        for t in targets:
            if p.applies(t):
                report.violations.extend(p.run(t))
    report.targets_run = [t.name for t in targets]

    if with_ownership:
        report.passes_run.append("pool-ownership")
        report.violations.extend(
            lint_ownership(src_root or DEFAULT_SRC_ROOT))

    if baseline is None:
        baseline = load_baseline(DEFAULT_BASELINE)
    elif not isinstance(baseline, dict):
        baseline = load_baseline(baseline)
    report.apply_baseline(baseline)
    return report
