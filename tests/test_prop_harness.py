"""The seeded property harness itself: determinism, coverage, shrinking."""

import pytest

from _prop import given, settings, strategies as st


class TestDrawing:
    def test_deterministic_across_runs(self):
        seen = []

        @given(x=st.integers(0, 1000), xs=st.lists(st.booleans(), max_size=5))
        @settings(max_examples=10)
        def collect(x, xs):
            seen.append((x, tuple(xs)))

        collect()
        first = list(seen)
        seen.clear()
        collect()
        assert seen == first, "same seeds must draw the same cases"

    def test_respects_bounds_and_example_count(self):
        draws = []

        @given(n=st.integers(3, 7),
               t=st.tuples(st.integers(0, 1), st.booleans()),
               p=st.sampled_from(["a", "b"]))
        @settings(max_examples=25)
        def collect(n, t, p):
            draws.append(n)
            assert 3 <= n <= 7
            assert t[0] in (0, 1) and isinstance(t[1], bool)
            assert p in ("a", "b")

        collect()
        assert len(draws) == 25
        assert len(set(draws)) > 1, "cases must actually vary"

    def test_list_sizes_within_range(self):
        @given(xs=st.lists(st.integers(0, 9), min_size=2, max_size=6))
        @settings(max_examples=30)
        def check(xs):
            assert 2 <= len(xs) <= 6

        check()

    def test_settings_respected_in_either_decorator_order(self):
        """@settings above OR below @given must set the example count —
        both orders are valid with real hypothesis."""
        for build in (
            lambda body: settings(max_examples=7)(given(x=st.booleans())(body)),
            lambda body: given(x=st.booleans())(settings(max_examples=7)(body)),
        ):
            runs = []
            build(lambda x: runs.append(x))()
            assert len(runs) == 7


class TestFailureReporting:
    def test_failure_is_shrunk_and_reported(self):
        @given(xs=st.lists(st.integers(0, 100), min_size=0, max_size=50))
        @settings(max_examples=50)
        def prop(xs):
            assert sum(xs) < 120        # fails for big-enough lists

        with pytest.raises(AssertionError, match="minimal failing case"):
            prop()

        # the shrunk case embedded in the message should still fail, and the
        # greedy minimizer should have reduced it well below the raw draw
        try:
            prop()
        except AssertionError as e:
            msg = str(e)
            case = eval(msg.split("minimal failing case: ")[1])
            assert sum(case["xs"]) >= 120
            assert len(case["xs"]) <= 20, "shrinking made no progress"

    def test_passing_property_raises_nothing(self):
        @given(b=st.booleans())
        @settings(max_examples=5)
        def prop(b):
            assert b in (True, False)

        prop()
