"""Property-based tests of the reference near-segment policies.

These exercise the object oracle (`repro.tier.reference`) through the
`repro.core.policies` compatibility shim; decision-for-decision parity of
the vectorized engines is covered by ``tests/test_tier_parity.py``.
Hypothesis drives the properties when installed; otherwise the seeded
fallback harness (``tests/_prop.py``) runs them, so this suite never skips.
"""

try:                                   # optional fast path: real hypothesis
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:                    # seeded fallback harness (tests/_prop)
    from _prop import given, settings, strategies as st

from repro.core.policies import (  # noqa: E402
    CacheState, PolicyCosts, make_policy,
)

COSTS = PolicyCosts(near_cost=23.4, far_cost=65.8, migrate_cost=69.8)


def _drive(policy_name, accesses, capacity=4):
    """Replays an access stream through a policy; returns final state."""
    pol = make_policy(policy_name, COSTS)
    st_ = CacheState(capacity=capacity)
    now = 0.0
    for i, (row, is_write) in enumerate(accesses):
        now += 10.0
        in_near = st_.hit(row)
        pol.on_access(st_, row, now, is_write, in_near, activated=True)
        if not in_near:
            d = pol.decide(st_, row, now, bank_idle=True)
            if d.promote:
                pol.apply_promotion(st_, row, d)
        if i % 16 == 15:
            pol.decay_scores(st_)
    return st_


rows = st.integers(min_value=0, max_value=30)
accesses = st.lists(st.tuples(rows, st.booleans()), min_size=1, max_size=300)


class TestCacheInvariants:
    @given(accesses=accesses, policy=st.sampled_from(["SC", "WMC", "BBC"]))
    @settings(max_examples=150, deadline=None)
    def test_lookup_slots_consistent(self, accesses, policy):
        s = _drive(policy, accesses)
        # every lookup entry points at a slot holding that row
        for row, slot in s.lookup.items():
            assert s.slots[slot] == row
        # every filled slot has a lookup entry
        filled = [r for r in s.slots if r is not None]
        assert sorted(filled) == sorted(s.lookup)
        assert len(set(filled)) == len(filled)  # no duplicates

    @given(accesses=accesses, policy=st.sampled_from(["SC", "WMC", "BBC"]))
    @settings(max_examples=150, deadline=None)
    def test_capacity_never_exceeded(self, accesses, policy):
        s = _drive(policy, accesses)
        assert s.occupancy() <= s.capacity

    @given(accesses=accesses)
    @settings(max_examples=100, deadline=None)
    def test_dirty_rows_are_cached(self, accesses):
        s = _drive("SC", accesses)
        assert s.dirty <= set(s.lookup)

    @given(accesses=accesses)
    @settings(max_examples=100, deadline=None)
    def test_scores_nonnegative(self, accesses):
        s = _drive("BBC", accesses)
        assert all(v >= 0 for v in s.score.values())


class TestSCBehaviour:
    def test_sc_caches_every_far_access(self):
        s = _drive("SC", [(1, False), (2, False), (3, False)])
        assert s.hit(1) and s.hit(2) and s.hit(3)

    def test_sc_lru_eviction(self):
        seq = [(r, False) for r in (1, 2, 3, 4, 1, 5)]  # cap 4: evicts 2
        s = _drive("SC", seq)
        assert s.hit(5) and s.hit(1)
        assert not s.hit(2)


class TestBBCBehaviour:
    def test_bbc_ignores_one_shot_rows(self):
        """Streaming rows (single activation) must not trigger migrations."""
        s = _drive("BBC", [(r, False) for r in range(25)])
        assert s.occupancy() == 0

    def test_bbc_promotes_reused_rows(self):
        seq = [(7, False)] * 6 + [(9, False)] * 6
        s = _drive("BBC", seq)
        assert s.hit(7) and s.hit(9)

    def test_bbc_prefers_hot_over_cold(self):
        # fill with moderately-hot rows, then hammer one row; it must displace
        # the coldest entry.
        seq = ([(r, False) for r in (1, 2, 3, 4)] * 3
               + [(10, False)] * 12)
        s = _drive("BBC", seq)
        assert s.hit(10)


class TestStaticProfile:
    def test_preload_places_hottest(self):
        pol = make_policy("STATIC", COSTS)
        s = CacheState(capacity=2)
        pol.preload(s, {5: 100, 6: 50, 7: 10})
        assert s.hit(5) and s.hit(6) and not s.hit(7)
        d = pol.decide(s, 7, 0.0, bank_idle=True)
        assert not d.promote
