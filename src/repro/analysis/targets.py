"""The registered jitted step factories the invariant passes run over.

One ``AnalysisTarget`` per jitted program the serving stack actually
executes — the dense/gather/fused decode step, pool prefill, suffix
prefill, the score walk, the scoring pass, the core read, and migration
planning — built over a distinctive-dimension config matrix so forbidden
shapes cannot collide with legitimate ones by accident:

    B=5 slots, n_pages=7 pages/slot, C=3 near pages, page=8 tokens,
    P=37 pool pages  =>  (B, n_pages, C)=(5,7,3) and the batched far view
    (B, n_pages*page, Hkv, hd)=(5, 56, Hkv, hd) appear nowhere in a clean
    trace.

The kernel mode comes from ``REPRO_KERNEL_MODE`` (dense | gather | fused)
— the same knob the CI test matrix uses — so one run of
``python -m repro.analysis`` audits exactly one read-path configuration
and CI fans out over all three.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from repro.analysis import walker

KERNEL_MODES = ("dense", "gather", "fused")

# Distinctive dims (mirrors the retired private pin in test_fused_serving):
# every forbidden shape below is reachable ONLY by rebuilding the construct
# the pass bans.
B, N_PAGES, C, PAGE = 5, 7, 3, 8
POOL_PAGES = B * N_PAGES + 2          # 37
MAX_LEN = N_PAGES * PAGE              # 56

# Substrings of arg-tree key paths that hold raw KV bytes (pool / near /
# far buffers, gathered prefix rows).  These seed the walker's RAW taint.
KV_KEYS = ("pool_k", "pool_v", "near_k", "near_v", "far_k", "far_v")


def kernel_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_MODE", "dense").lower()
    if mode not in KERNEL_MODES:
        raise ValueError(f"REPRO_KERNEL_MODE={mode!r}: want one of "
                         f"{KERNEL_MODES}")
    return mode


@dataclass
class ForbiddenShape:
    shape: tuple
    rule: str                    # e.g. "b-npages-c" / "batched-far-view"
    reason: str


@dataclass
class AnalysisTarget:
    """One jitted program under analysis.

    fn/args are traced lazily (``jaxpr`` memoizes); ``kv_keys`` substrings
    and ``kv_args`` positional indices mark the raw-KV invars that seed the
    taint lattice; ``forbidden_shapes`` parameterizes the no-dense-far-view
    pass per target; ``check_collectives`` additionally compiles the target
    and greps the optimized HLO for collective ops (the migration pin).
    """
    name: str
    fn: Callable
    args: tuple
    kv_keys: tuple = KV_KEYS
    kv_args: tuple = ()          # top-level positional args that ARE raw KV
    forbidden_shapes: tuple = ()
    per_tick: bool = True        # no-host-sync applies
    check_collectives: bool = False
    allowed_axes: tuple = ()     # mesh axes the no-collectives pass accepts
                                 # jaxpr collectives over (declared in
                                 # analysis_baseline.json "allowed_axes" —
                                 # the runner merges them by target name)
    _jaxpr: object = field(default=None, repr=False)

    def jaxpr(self):
        if self._jaxpr is None:
            self._jaxpr = jax.make_jaxpr(self.fn)(*self.args)
        return self._jaxpr

    def kv_invars(self) -> list[int]:
        def is_kv(keystr: str) -> bool:
            if any(k in keystr for k in self.kv_keys):
                return True
            for i in self.kv_args:
                if keystr.startswith(f"[{i}]"):
                    return True
            return False
        return walker.kv_invar_indices(self.args, is_kv)

    def walk(self) -> list:
        return walker.collect_eqns(self.jaxpr(), kv_invars=self.kv_invars())

    def hlo_text(self) -> str:
        return walker.lower_hlo_text(self.fn, *self.args)


def _forbidden(arch, mode: str, read_path: bool) -> tuple:
    """The shape bans for one target: the (B, n_pages, C) equality tensor is
    banned everywhere (the PR-5 metadata-hoisting invariant); the batched
    far view is banned only where the mode promises not to materialize it
    (fused read paths, and metadata-only targets in every mode)."""
    Hkv, hd = arch.n_kv_heads, arch.resolved_head_dim
    bans = [ForbiddenShape(
        (B, N_PAGES, C), "b-npages-c",
        "per-layer (B, n_pages, C) equality tensor — read metadata must be "
        "hoisted (computed once per step from the page tables)")]
    if not read_path or mode == "fused":
        bans.append(ForbiddenShape(
            (B, N_PAGES * PAGE, Hkv, hd), "batched-far-view",
            "batched far view (B, n_pages*page, Hkv, hd) — the fused path "
            "must walk the page table, never materialize the far tier"))
    return tuple(bans)


def build_targets(mode: str | None = None) -> list[AnalysisTarget]:
    """Trace the registered step factories under one kernel mode."""
    from repro.configs.registry import ARCHS
    from repro.core import tiered_kv as tkv
    from repro.launch.serve import (make_paged_tiered_decode_step,
                                    make_pool_prefill_step,
                                    make_pool_suffix_prefill_step)
    from repro.models import transformer

    mode = mode or kernel_mode()
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(0), arch)
    cfg = tkv.TieredKVConfig(page=PAGE, near_pages=C, policy="BBC",
                             gather_kernel=(mode == "gather"),
                             fused_kernel=(mode == "fused"))
    L = arch.n_layers
    Hkv, hd = arch.n_kv_heads, arch.resolved_head_dim
    H = arch.n_heads

    paged = tkv.init_paged_cache(cfg, B, N_PAGES, POOL_PAGES, Hkv, hd)
    pos = jnp.full((B,), 2 * PAGE + 3, jnp.int32)
    q = jnp.zeros((B, H, hd), jnp.float32)
    targets: list[AnalysisTarget] = []

    # 1. core two-tier read (the oracle / gather / fused read primitive)
    targets.append(AnalysisTarget(
        name="paged_attention_read",
        fn=lambda c, qq, p: tkv.paged_tiered_attention(c, qq, p, cfg),
        args=(paged, q, pos),
        forbidden_shapes=_forbidden(arch, mode, read_path=True)))

    # 2. full transformer decode step (pool-native cache, meta hoisted)
    pools = {
        "pos": pos,
        "pool_k": jnp.zeros((L, POOL_PAGES, PAGE, Hkv, hd), jnp.bfloat16),
        "pool_v": jnp.zeros((L, POOL_PAGES, PAGE, Hkv, hd), jnp.bfloat16),
        "near_k": jnp.zeros((L, C * PAGE, Hkv, hd), jnp.bfloat16),
        "near_v": jnp.zeros((L, C * PAGE, Hkv, hd), jnp.bfloat16),
    }
    meta = tkv.paged_step_metadata(paged, pos + 1, cfg, append_pos=pos)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    decode = make_paged_tiered_decode_step(arch, cfg)
    targets.append(AnalysisTarget(
        name="paged_decode_step",
        fn=lambda c, b, m: decode(params, c, b, m),
        args=(pools, batch, meta),
        forbidden_shapes=_forbidden(arch, mode, read_path=True)))

    # 2b. mesh-sharded decode step (docs/design.md §2h), registered only
    # where a multi-device mesh exists (the mesh-4dev CI leg forces one
    # with XLA_FLAGS) and in fused mode (the production-shaped read path).
    # check_collectives=True with the baseline-declared allowed_axes: the
    # shard_map stats gathers over 'model' are by-design; anything else —
    # an undeclared axis, or a GSPMD-inserted reshard of a kind the
    # declared collectives don't account for — fails the pass.
    if mode == "fused" and jax.device_count() > 1 \
            and arch.n_kv_heads % 2 == 0:
        import dataclasses as _dc
        from repro.launch.mesh import make_test_mesh
        scfg = _dc.replace(cfg, mesh=make_test_mesh(2))
        sdecode = make_paged_tiered_decode_step(arch, scfg)
        targets.append(AnalysisTarget(
            name="paged_decode_step_sharded",
            fn=lambda c, b, m: sdecode(params, c, b, m),
            args=(pools, batch, meta),
            forbidden_shapes=_forbidden(arch, mode, read_path=True),
            check_collectives=True))

    # 3./4. pool prefill + shared-prefix suffix prefill (dense rows are a
    # transient inside the step; only the pool survives)
    prefill = make_pool_prefill_step(arch, MAX_LEN, PAGE)
    pbatch = {"tokens": jnp.zeros((1, 16), jnp.int32)}
    ids = jnp.arange(N_PAGES, dtype=jnp.int32)
    targets.append(AnalysisTarget(
        name="pool_prefill",
        fn=lambda b, pk, pv, i: prefill(params, b, pk, pv, i),
        args=(pbatch, pools["pool_k"], pools["pool_v"], ids),
        kv_args=(1, 2),                        # pool buffers are positional
        per_tick=False,
        forbidden_shapes=(_forbidden(arch, mode, read_path=True)[0],)))

    from repro.launch.serve import make_pool_chunk_prefill_step

    sfx = make_pool_suffix_prefill_step(arch, MAX_LEN, PAGE)
    m_pre = 2                                  # matched shared-prefix pages
    sbatch = {"tokens": jnp.zeros((1, 16), jnp.int32),
              "positions": m_pre * PAGE
              + jnp.arange(16, dtype=jnp.int32)[None]}
    kpre = jnp.zeros((L, 1, m_pre * PAGE, Hkv, hd), jnp.bfloat16)
    targets.append(AnalysisTarget(
        name="suffix_prefill",
        fn=lambda b, kp, vp, pk, pv, i: sfx(params, b, kp, vp, pk, pv, i),
        args=(sbatch, kpre, kpre, pools["pool_k"], pools["pool_v"], ids),
        kv_args=(1, 2, 3, 4),                  # prefix rows + pool buffers
        per_tick=False,
        forbidden_shapes=(_forbidden(arch, mode, read_path=True)[0],)))

    # 4b. chunk-resumable admission prefill (ISSUE 8): resumes from a
    # mid-prompt cursor — the prefix rows are gathered from the pool
    # INSIDE the step (one program: gather + suffix prefill + scatter), so
    # the pass set proves the chunked lane adds no host syncs and no
    # dense-far-view rebuild beyond the transient the prefill owns
    chunk = make_pool_chunk_prefill_step(arch, MAX_LEN, PAGE)
    t_pre = 2 * PAGE + 3                       # cursor mid-page on purpose
    chbatch = {"tokens": jnp.zeros((1, 16), jnp.int32),
               "positions": t_pre
               + jnp.arange(16, dtype=jnp.int32)[None]}
    pre_ids = jnp.arange(3, dtype=jnp.int32)   # ceil(t_pre/PAGE) pages
    targets.append(AnalysisTarget(
        name="chunk_prefill",
        fn=lambda b, pk, pv, pi, i: chunk(params, b, pk, pv, pi, i,
                                          t_pre=t_pre),
        args=(chbatch, pools["pool_k"], pools["pool_v"], pre_ids, ids),
        kv_args=(1, 2),
        per_tick=False,
        forbidden_shapes=(_forbidden(arch, mode, read_path=True)[0],)))

    # 5. score walk: pure page-table metadata — may touch NO KV bytes and
    # build nothing far-view-shaped in any mode
    targets.append(AnalysisTarget(
        name="paged_score_walk",
        fn=lambda c, p: tkv.paged_score_walk(c, p, cfg),
        args=({"page_table": paged["page_table"]}, pos),
        forbidden_shapes=_forbidden(arch, mode, read_path=False)))

    # 6. scoring pass (per-page attention mass; fused mode walks, dense
    # mode materializes the oracle view)
    targets.append(AnalysisTarget(
        name="paged_page_masses",
        fn=lambda qq, c, p: tkv.paged_page_masses(qq, c, p, cfg),
        args=(q, paged, pos),
        forbidden_shapes=_forbidden(arch, mode, read_path=True)))

    # 7. monolithic migration planning — the IST analogue: pure on-device
    # page copies, asserted collective-free in optimized HLO (the pin from
    # tests/test_tiered_runtime.py, now routed through the framework)
    mono_cfg = tkv.TieredKVConfig(page=PAGE, near_pages=C, policy="BBC")
    kc = jnp.zeros((B, MAX_LEN, Hkv, hd), jnp.bfloat16)
    mono = tkv.init_tiered_cache(kc, kc, mono_cfg)
    targets.append(AnalysisTarget(
        name="plan_and_migrate",
        fn=lambda c, qq, p: tkv.plan_and_migrate(c, qq, p, mono_cfg),
        args=(mono, q, pos),
        check_collectives=True,
        forbidden_shapes=(_forbidden(arch, mode, read_path=False)[0],)))

    return targets
