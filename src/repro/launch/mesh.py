"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set XLA_FLAGS before any
jax initialization.  In particular the forced-host CPU multi-device mode
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) only takes effect
when the flag is set before the first backend touch, which is how the
``mesh-4dev`` CI leg and tests/test_mesh_serving.py get a real 4-device
mesh on a CPU runner.

Axis contract (docs/design.md §2h):

  'data'  : engine replicas — each owns a slot pool + radix prefix cache
            and serves its share of admissions (scheduler round-robin).
  'model' : KV-head sharding of the page pool / near buffers — each device
            walks only its head slice of every mapped page inside
            ``shard_map``; page tables and walk metadata stay replicated.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def _mesh_over(devices, shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    n = int(np.prod(shape))
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod (v5e pod); 2 pods = 512 chips multi-pod.

    On hosts with fewer devices (CPU runs — including the forced-host
    multi-device mode) the pod shapes are unbuildable; the old behavior
    silently degraded to a 1-device mesh, which made nothing mesh-shaped
    testable.  Now: fall back DETERMINISTICALLY to a ('data','model') mesh
    over every local device, with the 'model' axis as large as possible
    (the KV-head shard axis is the one the serving read path exercises)
    — n devices => shape (1, n)."""
    devices = jax.devices()
    n = len(devices)
    if multi_pod and n >= 512:
        return _mesh_over(devices, (2, 16, 16), ("pod", "data", "model"))
    if n >= 256:
        return _mesh_over(devices, (16, 16), ("data", "model"))
    return _mesh_over(devices, (1, n), ("data", "model"))


def make_test_mesh(n: int | None = None, *, data: int = 1) -> Mesh:
    """Deterministic ('data','model') mesh over the first ``data * model``
    local devices — the tests' entry point (model = n // data).

    ``n`` defaults to every local device.  Callers should skip when
    ``jax.device_count() < n`` (the default CI legs run on 1 device; the
    ``mesh-4dev`` leg forces 4 via XLA_FLAGS)."""
    avail = jax.device_count()
    n = avail if n is None else n
    if n > avail:
        raise ValueError(f"make_test_mesh({n}) on a {avail}-device host — "
                         f"set XLA_FLAGS=--xla_force_host_platform_"
                         f"device_count={n} before jax initializes")
    if n % data:
        raise ValueError(f"device count {n} not divisible by data={data}")
    return _mesh_over(jax.devices(), (data, n // data), ("data", "model"))


def make_host_mesh() -> Mesh:
    """Whatever devices exist locally (tests / examples), as a 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
