"""Compatibility shim — the vectorized policies now live in ``repro.tier``.

The jittable planning functions formerly defined here (BBC-only) moved to
`repro.tier.jax_engine` and were generalized to all four paper policies
(SC / WMC / BBC / STATIC) on top of the shared decision core in
`repro.tier.rules`; the cost dataclass is the unified
`repro.tier.costs.TierCosts`.  See docs/tier.md.
"""

from __future__ import annotations

from repro.tier.costs import TierCosts  # noqa: F401
from repro.tier.jax_engine import (  # noqa: F401
    apply_promotions,
    ema_update,
    plan_promotions,
    preload_static,
)
