"""Model zoo: build functions + input specs for every (arch x shape) cell.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input (weak-type-correct, shardable, no device allocation) — the dry-run
pattern.  ``make_batch`` materializes small real batches for smoke tests and
examples.  Modality frontends (vision patches / audio frames) are stubs per
the assignment: precomputed embeddings of the documented shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape

N_PATCHES_STUB = 256          # vision prefix length (qwen2-vl dynamic-res stub)


def n_patches(seq_len: int) -> int:
    """Vision prefix length, capped so small smoke sequences stay valid."""
    return min(N_PATCHES_STUB, seq_len // 4)


def input_specs(arch: ArchConfig, shape: InputShape,
                compute_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for one (arch, shape) cell.

    train/prefill: full (B, S) token batch (+ labels for train).
    decode: one new token per sequence; the KV/state cache is separate (see
    ``transformer.init_cache``) and sized for shape.seq_len.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        if arch.family == "audio":
            specs["frame_embeds"] = jax.ShapeDtypeStruct((B, S, arch.d_model),
                                                         compute_dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if arch.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, n_patches(S), arch.d_model), compute_dtype)
            specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
        if shape.kind == "train":
            if arch.family == "audio":
                specs["labels"] = jax.ShapeDtypeStruct(
                    (B, S, arch.n_codebooks), i32)
            else:
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one token per sequence
        if arch.family == "audio":
            specs["frame_embeds"] = jax.ShapeDtypeStruct((B, 1, arch.d_model),
                                                         compute_dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    return specs


def cache_specs(arch: ArchConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs matching ``transformer.init_cache`` for decode."""
    from repro.models.transformer import init_cache

    shapes = jax.eval_shape(
        lambda: init_cache(arch, shape.global_batch, shape.seq_len, dtype))
    return shapes


def make_batch(arch: ArchConfig, shape: InputShape, seed: int = 0,
               compute_dtype=jnp.float32) -> dict:
    """A small real batch (for smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, spec in input_specs(arch, shape, compute_dtype).items():
        if spec.dtype == jnp.int32:
            hi = arch.vocab if "token" in name or "label" in name else shape.seq_len
            out[name] = jnp.asarray(
                rng.integers(0, hi, size=spec.shape, dtype=np.int32))
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(spec.shape) * 0.02, dtype=spec.dtype)
    if "positions" in out:  # monotone positions for M-RoPE
        B, S, _ = out["positions"].shape
        pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, :, None],
                              (B, S, 3))
        out["positions"] = jnp.asarray(pos)
    return out


def flops_per_token(arch: ArchConfig, training: bool = True) -> float:
    """MODEL_FLOPS: 6*N*D convention (fwd 2ND + bwd 4ND), active params."""
    n = arch.active_param_count()
    return (6.0 if training else 2.0) * n
