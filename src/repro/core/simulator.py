"""Event-driven DRAM system simulator (Ramulator-lite) with TL-DRAM support.

Reproduces the evaluation methodology of the paper (Sec. 5): trace-driven
cores with a limited run-ahead window (MLP) issue cache-line requests to a
shared memory controller (FR-FCFS, open-row policy) over one channel and
multiple banks; each bank's subarrays optionally carry a TL-DRAM near-segment
cache managed by one of the four policies in ``repro.tier`` (SC / WMC / BBC /
STATIC), driven through the vectorized `repro.tier.engine.TierEngine` whose
state is batched across the whole bank x subarray grid.

Fidelity notes (deliberate simplifications, standard for lightweight sims):
  * request-granular bank serialization (per-bank command pipelining is folded
    into the tRCD/tRAS/tRP/tRC window arithmetic);
  * single rank, no tFAW/tRRD; data-bus contention is modeled exactly;
  * writes share the read column path plus a tWR write-recovery window;
  * all-bank refresh every tREFI occupying tRFC.

Inter-Segment Data Transfer (IST) follows the paper: it occupies the *bank*
for tRC(far) + 4 ns but never the channel, so accesses to other banks proceed
concurrently — asserted by ``tests/test_simulator.py``.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core import power, timing
from repro.tier import TierCosts, TierEngine

CPU_GHZ = 3.2
ISSUE_WIDTH = 4
ROWS_PER_SUBARRAY = 512


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DeviceConfig:
    """One DRAM device model."""

    kind: str                      # 'commodity' | 'short' | 'tldram'
    near_rows: int = 32            # TL-DRAM near-segment rows per subarray
    total_rows: int = ROWS_PER_SUBARRAY
    policy: str = "BBC"            # TL-DRAM near-segment policy
    banks: int = 8
    subarrays_per_bank: int = 16

    def addressable_rows(self) -> int:
        """Rows exposed to the system per subarray (cache mode hides near)."""
        if self.kind == "tldram":
            return self.total_rows - self.near_rows
        return self.total_rows


@dataclass(frozen=True)
class SimConfig:
    device: DeviceConfig
    mlp: int = 8                   # max outstanding requests per core
    refresh: bool = True
    policy_decay_period: int = 16  # accesses between score decays per subarray


# --------------------------------------------------------------------------
# Workload traces
# --------------------------------------------------------------------------

@dataclass
class Trace:
    """Per-core memory trace.

    gaps[i]   : non-memory instructions before request i
    banks[i]  : bank index
    subarrays[i], rows[i] : subarray / row-within-subarray (far address space)
    writes[i] : bool
    """

    gaps: np.ndarray
    banks: np.ndarray
    subarrays: np.ndarray
    rows: np.ndarray
    writes: np.ndarray

    def __len__(self) -> int:
        return len(self.rows)


# --------------------------------------------------------------------------
# Results
# --------------------------------------------------------------------------

@dataclass
class CoreStats:
    instructions: int = 0
    requests: int = 0
    run_ns: float = 0.0

    @property
    def ipc(self) -> float:
        cycles = self.run_ns * CPU_GHZ
        return self.instructions / cycles if cycles else 0.0


@dataclass
class SimResult:
    cores: list[CoreStats]
    near_hits: int = 0
    far_accesses: int = 0
    normal_accesses: int = 0
    row_hits: int = 0
    acts_by_class: dict = field(default_factory=dict)
    migrations: int = 0
    writebacks: int = 0
    energy_nj: float = 0.0
    run_ns: float = 0.0
    total_read_latency_ns: float = 0.0
    reads: int = 0

    @property
    def near_hit_rate(self) -> float:
        tot = self.near_hits + self.far_accesses
        return self.near_hits / tot if tot else 0.0

    @property
    def power_mw(self) -> float:
        return self.energy_nj / self.run_ns * 1e3 if self.run_ns else 0.0

    @property
    def avg_read_latency_ns(self) -> float:
        return self.total_read_latency_ns / self.reads if self.reads else 0.0

    def weighted_speedup(self, alone: "list[SimResult]") -> float:
        return sum(c.ipc / a.cores[0].ipc
                   for c, a in zip(self.cores, alone))


# --------------------------------------------------------------------------
# Internal state
# --------------------------------------------------------------------------

@dataclass
class _Bank:
    queue: list = field(default_factory=list)     # pending request ids
    busy: bool = False
    open_key: tuple | None = None                 # (class, subarray, phys_row)
    open_ts: timing.TimingSet | None = None       # timings of the open row
    ready_col: float = 0.0
    ready_pre: float = 0.0
    ready_act: float = 0.0


@dataclass
class _Core:
    trace: Trace
    ptr: int = 0
    clock_ns: float = 0.0                         # run-ahead frontier
    outstanding: list = field(default_factory=list)  # FIFO of request ids
    done: bool = False
    stats: CoreStats = field(default_factory=CoreStats)
    # Trace columns as Python lists: the controller touches them per request
    # (FR-FCFS scans classify up to 16 queued requests per serve), and list
    # indexing is ~5x cheaper than NumPy scalar extraction.
    gaps_l: list = field(default_factory=list)
    groups_l: list = field(default_factory=list)  # bank*subarrays + subarray
    subs_l: list = field(default_factory=list)
    rows_l: list = field(default_factory=list)
    writes_l: list = field(default_factory=list)


class _Event:
    ARRIVAL = 0
    BANK_DONE = 1
    REFRESH = 2


class DRAMSystem:
    """One simulation run: ``DRAMSystem(cfg, traces).run()``."""

    def __init__(self, cfg: SimConfig, traces: list[Trace]):
        self.cfg = cfg
        dev = cfg.device
        self.dev = dev
        self.banks = [_Bank() for _ in range(dev.banks)]
        self.channel_free = 0.0
        self.result = SimResult(cores=[])
        self.events: list = []
        self._seq = 0

        # Timing sets per access class.
        if dev.kind == "commodity":
            self.ts_normal = timing.ddr3_baseline(dev.total_rows)
        elif dev.kind == "short":
            self.ts_normal = timing.short_bitline(dev.near_rows)
        elif dev.kind == "tldram":
            self.ts_near, self.ts_far = timing.tldram_timings(
                dev.near_rows, dev.total_rows)
            self.ist_ns = timing.ist_duration_ns(self.ts_far)
        else:
            raise ValueError(dev.kind)

        # Energies per access class.
        far_cells = dev.total_rows - dev.near_rows
        self.e_normal = power.unsegmented_access_energy(dev.total_rows)
        self.e_short = power.unsegmented_access_energy(dev.near_rows)
        self.e_near = power.near_access_energy(dev.near_rows)
        self.e_far = power.far_access_energy(dev.near_rows, far_cells)
        self.e_ist = power.ist_energy_nj(dev.near_rows, far_cells)

        # TL-DRAM near-segment state: one vectorized engine batched across
        # the whole bank x subarray grid (group g = bank * subarrays + s).
        if dev.kind == "tldram":
            costs = TierCosts(near_cost=self.ts_near.t_rc,
                              far_cost=self.ts_far.t_rc,
                              migrate_cost=self.ist_ns)
            # rows = total_rows (not addressable_rows): trace generators may
            # address the full far row space regardless of the near-segment
            # capacity sweep (the old dict state was unbounded the same way).
            self.tier: TierEngine | None = TierEngine(
                dev.policy, costs,
                groups=dev.banks * dev.subarrays_per_bank,
                rows=dev.total_rows, capacity=dev.near_rows,
                decay_period=cfg.policy_decay_period)
        else:
            self.tier = None

        self.cores = [_Core(trace=t) for t in traces]
        for c in self.cores:
            c.stats.requests = len(c.trace)
            c.stats.instructions = int(c.trace.gaps.sum()) + len(c.trace)
            t = c.trace
            c.gaps_l = t.gaps.tolist()
            c.groups_l = (t.banks * dev.subarrays_per_bank
                          + t.subarrays).tolist()
            c.subs_l = t.subarrays.tolist()
            c.rows_l = t.rows.tolist()
            c.writes_l = t.writes.tolist()
        # Request bookkeeping: flat arrays indexed by (core, idx).
        self.req_issue_ns: dict[tuple[int, int], float] = {}

        if self.tier is not None and self.tier.policy == "STATIC":
            self._static_preload()

    # -- static profiling (OS-exposed mechanism) ----------------------------

    def _group(self, bank: int, subarray: int) -> int:
        return bank * self.dev.subarrays_per_bank + subarray

    def _static_preload(self):
        """Whole-trace profile (counts + first occurrence per row), built
        vectorized and handed to the engine's t=0 placement."""
        G, N = self.tier.G, self.tier.N
        counts = np.zeros((G, N))
        first = np.full((G, N), np.iinfo(np.int64).max, np.int64)
        offset = 0
        for core in self.cores:
            t = core.trace
            g = t.banks * self.dev.subarrays_per_bank + t.subarrays
            np.add.at(counts, (g, t.rows), 1.0)
            np.minimum.at(first, (g, t.rows),
                          offset + np.arange(len(t), dtype=np.int64))
            offset += len(t)
        self.tier.preload(counts, first)

    # -- event plumbing -----------------------------------------------------

    def _push(self, t: float, kind: int, payload) -> None:
        self._seq += 1
        heapq.heappush(self.events, (t, self._seq, kind, payload))

    # -- core model -----------------------------------------------------------

    def _core_try_issue(self, ci: int, now: float) -> None:
        core = self.cores[ci]
        while (core.ptr < len(core.trace)
               and len(core.outstanding) < self.cfg.mlp):
            gap = core.gaps_l[core.ptr]
            issue = max(core.clock_ns + gap / ISSUE_WIDTH / CPU_GHZ, now)
            core.clock_ns = issue
            rid = (ci, core.ptr)
            core.outstanding.append(rid)
            core.ptr += 1
            self.req_issue_ns[rid] = issue
            self._push(issue, _Event.ARRIVAL, rid)

    def _core_complete(self, ci: int, rid, now: float) -> None:
        core = self.cores[ci]
        # In-order window: the oldest outstanding request gates retirement.
        core.outstanding.remove(rid)
        core.clock_ns = max(core.clock_ns, now)
        self._core_try_issue(ci, now)
        if core.ptr >= len(core.trace) and not core.outstanding and not core.done:
            core.done = True
            core.stats.run_ns = core.clock_ns

    # -- controller ------------------------------------------------------------

    def _classify(self, rid) -> tuple[str, tuple, timing.TimingSet, int]:
        """Access class, open-row key, timings, tier group for a request."""
        ci, idx = rid
        core = self.cores[ci]
        s, r = core.subs_l[idx], core.rows_l[idx]
        if self.tier is None:
            cls = "normal" if self.dev.kind == "commodity" else "short"
            return cls, ("row", s, r), self.ts_normal, -1
        g = core.groups_l[idx]
        slot = self.tier.slot(g, r)
        if slot >= 0:
            return "near", ("near", s, slot), self.ts_near, g
        return "far", ("far", s, r), self.ts_far, g

    def _select(self, bank: _Bank) -> int:
        """FR-FCFS: oldest row-hit first, else oldest (with an age cap the
        row-hit preference cannot starve FCFS order beyond 16 requests)."""
        if len(bank.queue) > 1 and bank.open_key is not None:
            for pos, rid in enumerate(bank.queue[:16]):
                if self._classify(rid)[1] == bank.open_key:
                    bank.queue.pop(pos)
                    return rid
        return bank.queue.pop(0)

    def _serve(self, bi: int, now: float) -> None:
        bank = self.banks[bi]
        if bank.busy or not bank.queue:
            return
        rid = self._select(bank)
        bank.busy = True

        cls, key, ts, g = self._classify(rid)
        ci, idx = rid
        core = self.cores[ci]
        is_write = core.writes_l[idx]

        activated = bank.open_key != key
        if not activated:
            self.result.row_hits += 1
            t_col = max(now, bank.ready_col)
        else:
            if bank.open_key is not None:
                t_pre = max(now, bank.ready_pre)
                t_act = max(t_pre + bank.open_ts.t_rp, bank.ready_act)
            else:
                t_act = max(now, bank.ready_act)
            bank.open_key, bank.open_ts = key, ts
            bank.ready_col = t_act + ts.t_rcd
            bank.ready_pre = t_act + ts.t_ras
            bank.ready_act = t_act + ts.t_rc  # earliest back-to-back ACT
            t_col = bank.ready_col
            self._account_activation(cls)

        data_start = max(t_col + ts.t_cl, self.channel_free)
        data_end = data_start + ts.t_bl
        self.channel_free = data_end
        if is_write:
            bank.ready_pre = max(bank.ready_pre, data_end + ts.t_wr)
            self.result.energy_nj += power.E_WRITE_NJ
        else:
            self.result.energy_nj += power.E_READ_NJ
            self.result.total_read_latency_ns += data_end - self.req_issue_ns[rid]
            self.result.reads += 1

        # Policy hooks (TL-DRAM only).
        busy_until = data_end
        if g >= 0:
            r = core.rows_l[idx]
            in_near = cls == "near"
            # on_access also runs the group's periodic score decay, matching
            # the on_access -> decay -> decide order of the old dict layer.
            self.tier.on_access(g, r, data_end, is_write, in_near,
                                activated=activated)
            if in_near:
                self.result.near_hits += 1
            else:
                self.result.far_accesses += 1
                decision = self.tier.decide(g, r, data_end,
                                            bank_idle=not bank.queue)
                if decision.promote:
                    cost = self.ist_ns
                    self.result.migrations += 1
                    self.result.energy_nj += self.e_ist
                    if decision.victim_dirty:
                        cost += self.ist_ns
                        self.result.writebacks += 1
                        self.result.energy_nj += self.e_ist
                    # IST occupies the bank (not the channel) and ends with
                    # the involved rows precharged.
                    busy_until = max(busy_until, bank.ready_pre) + cost
                    bank.open_key, bank.open_ts = None, None
                    bank.ready_act = max(bank.ready_act, busy_until)
                    self.tier.apply(g, r, decision)

        self._push(busy_until, _Event.BANK_DONE, (bi, rid, data_end))

    def _account_activation(self, cls: str) -> None:
        e = {"normal": self.e_normal, "short": self.e_short,
             "near": self.e_near, "far": self.e_far}[cls].act_pre_nj
        self.result.energy_nj += e
        acts = self.result.acts_by_class
        acts[cls] = acts.get(cls, 0) + 1
        if cls in ("normal", "short"):
            self.result.normal_accesses += 1

    # -- refresh -----------------------------------------------------------

    def _refresh(self, now: float) -> None:
        for bank in self.banks:
            start = max(now, bank.ready_pre if bank.open_key else now,
                        bank.ready_act)
            bank.open_key, bank.open_ts = None, None
            bank.ready_act = max(bank.ready_act, start + timing.T_RFC_NS)
        # 64 ms retention / tREFI => 8192 REF commands refresh every row once.
        total_rows = self.dev.banks * self.dev.subarrays_per_bank * self.dev.total_rows
        self.result.energy_nj += (total_rows / 8192.0) * power.E_REFRESH_PER_ROW_NJ
        self._push(now + timing.T_REFI_NS, _Event.REFRESH, None)

    # -- main loop -----------------------------------------------------------

    def run(self) -> SimResult:
        for ci in range(len(self.cores)):
            self._core_try_issue(ci, 0.0)
        if self.cfg.refresh:
            self._push(timing.T_REFI_NS, _Event.REFRESH, None)

        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            if kind == _Event.ARRIVAL:
                rid = payload
                ci, idx = rid
                bi = self.cores[ci].groups_l[idx] // self.dev.subarrays_per_bank
                self.banks[bi].queue.append(rid)
                self._serve(bi, t)
            elif kind == _Event.BANK_DONE:
                bi, rid, data_end = payload
                self.banks[bi].busy = False
                self._core_complete(rid[0], rid, data_end)
                self._serve(bi, t)
            elif kind == _Event.REFRESH:
                if any(not c.done for c in self.cores):
                    self._refresh(t)

        self.result.cores = [c.stats for c in self.cores]
        self.result.run_ns = max((c.stats.run_ns for c in self.cores), default=0.0)
        self.result.energy_nj += power.P_BACKGROUND_MW * 1e-3 * self.result.run_ns
        return self.result


def simulate(cfg: SimConfig, traces: list[Trace]) -> SimResult:
    return DRAMSystem(cfg, traces).run()


def simulate_alone(cfg: SimConfig, traces: list[Trace]) -> list[SimResult]:
    """Each trace run alone (for weighted-speedup baselines)."""
    return [DRAMSystem(cfg, [t]).run() for t in traces]
