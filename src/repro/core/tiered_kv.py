"""Tiered KV cache: the TL-DRAM near/far substrate applied to decode serving.

Mapping (docs/design.md §2b):

  far tier   : the full KV cache (master copy; new tokens append here) —
               the long-bitline segment.  Gather-addressed => slow path.
  near tier  : a small contiguous buffer of *copies* of hot KV pages —
               the near segment.  Dense, VMEM-streamable by the Pallas
               kernel (`kernels.tiered_attention`) => fast path.
  IST        : promotions/evictions are pure on-device page copies
               (`dynamic_update_slice`) — no collectives, no host round-trip,
               mirroring the paper's channel-free inter-segment transfer
               (asserted by tests: migration HLO contains no collective ops).
  policy     : every `interval` decode steps, a scoring pass measures per-page
               attention mass with the current queries (the paper's
               interval-sampled activation counts), EMA-updates page scores,
               and runs the shared vectorized engine (`repro.tier.jax_engine`)
               under any of the four paper policies — SC, WMC, BBC (default)
               or STATIC (profile preload via `preload_static`).

KV pages are immutable once written, so evictions are always clean (the
paper's dirty-eviction write-back IST never triggers for this workload — a
fact we note rather than hide).

Correctness invariant (tested): near+far partitioned attention with LSE merge
is *exactly* standard attention over the full cache.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.tier import TierCosts, ema_update
from repro.tier.jax_engine import (apply_promotions, plan_promotions,
                                   preload_static)
from repro.kernels import ops, ref

# Cost model (napkin math, documented in docs/experiments.md): far pages are
# gather-addressed — effective HBM bandwidth for 2KB-grain gathers is ~1/4 of
# streaming bandwidth on TPU-class memory systems; near pages stream at full
# bandwidth.  Migration copies a page (read + write) at streaming bandwidth.
DEFAULT_COSTS = TierCosts(near_cost=1.0, far_cost=4.0, migrate_cost=8.0,
                          hysteresis=2.0, min_score=2.0, decay=0.9)


@dataclass
class TieredKVConfig:
    page: int = 128               # tokens per page
    near_pages: int = 8           # near-tier capacity (pages per sequence)
    interval: int = 16            # decode steps between planning passes
    max_promotions: int = 2       # migrations per planning pass
    policy: str = "BBC"           # SC | WMC | BBC | STATIC
    costs: TierCosts = DEFAULT_COSTS


def init_tiered_cache(k_cache: jax.Array, v_cache: jax.Array,
                      cfg: TieredKVConfig) -> dict:
    """Wrap an existing (B, T, Hkv, hd) far cache with near-tier state."""
    B, T, Hkv, hd = k_cache.shape
    assert T % cfg.page == 0, f"cache length {T} must be a page multiple"
    n_pages = T // cfg.page
    C = cfg.near_pages
    return {
        "far_k": k_cache, "far_v": v_cache,
        "near_k": jnp.zeros((B, C * cfg.page, Hkv, hd), k_cache.dtype),
        "near_v": jnp.zeros((B, C * cfg.page, Hkv, hd), v_cache.dtype),
        "slot_of_page": -jnp.ones((B, n_pages), jnp.int32),
        "page_of_slot": -jnp.ones((B, C), jnp.int32),
        "scores": jnp.zeros((B, n_pages), jnp.float32),
        # SC/WMC LRU stamps: planning-interval index of each page's last
        # nonzero attention mass (BBC/STATIC ignore them).
        "last_use": jnp.zeros((B, n_pages), jnp.float32),
        "step": jnp.zeros((), jnp.int32),
        "migrations": jnp.zeros((), jnp.int32),
    }


def _pos_vec(pos, B: int) -> jax.Array:
    """Normalize a decode position to a per-sequence (B,) vector.

    Every read-path entry point accepts either the legacy scalar (one
    position shared by the whole batch) or a ragged per-slot vector (the
    continuous-batching serving engine's slot pool)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))
    return pos


def near_token_count(cache: dict, cfg: TieredKVConfig) -> jax.Array:
    """(B,) live near-tier token count.  Occupied slots always form a
    prefix (pinned by tests/test_read_path.py), so count * page is the
    exact live region the kernel streams."""
    occupied = (cache["page_of_slot"] >= 0)
    return occupied.sum(axis=1).astype(jnp.int32) * cfg.page


def reset_sequences(cache: dict, rows: jax.Array) -> dict:
    """Clear tier state for retired slots (rows: (B,) bool mask).

    The far/near K,V buffers are left untouched — a cleared mapping makes
    the near copies unreachable (near_len excludes them) and the next
    prefill overwrites the far rows; only the policy state must not leak
    into the slot's next tenant."""
    cache = dict(cache)
    r = rows[:, None]
    cache["slot_of_page"] = jnp.where(r, -1, cache["slot_of_page"])
    cache["page_of_slot"] = jnp.where(r, -1, cache["page_of_slot"])
    cache["scores"] = jnp.where(r, 0.0, cache["scores"])
    cache["last_use"] = jnp.where(r, 0.0, cache["last_use"])
    return cache


def append_token(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array) -> dict:
    """Append one token's K/V to the far tier (master copy).

    pos: scalar position, or a (B,) vector for ragged per-slot appends."""
    cache = dict(cache)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        b_idx = jnp.arange(k_new.shape[0])
        cache["far_k"] = cache["far_k"].at[b_idx, pos].set(k_new[:, 0])
        cache["far_v"] = cache["far_v"].at[b_idx, pos].set(v_new[:, 0])
    else:
        cache["far_k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["far_k"], k_new, pos, 1)
        cache["far_v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["far_v"], v_new, pos, 1)
    return cache


def tiered_attention(cache: dict, q: jax.Array, pos: jax.Array,
                     cfg: TieredKVConfig) -> jax.Array:
    """Two-tier decode attention.  q: (B,H,hd); pos: scalar current
    position, or a (B,) vector of ragged per-slot positions.

    Near path: Pallas kernel over the contiguous near buffer.
    Far path: XLA attention over the far cache, with promoted pages masked
    out (they are served from the near tier) and positions >= pos masked.
    """
    B, H, hd = q.shape
    T = cache["far_k"].shape[1]
    page = cfg.page
    pos = _pos_vec(pos, B)

    # Near tier: occupied slots always form a prefix (promotions fill empty
    # slots in index order and evictions replace in place), so the live
    # region is simply count * page.
    near_len = near_token_count(cache, cfg)

    out_n, m_n, l_n = _near_stats(q, cache, near_len, cfg)

    # far mask: slot < pos and the slot's page is not promoted
    slots = jnp.arange(T)
    page_of_slot_idx = slots // page                        # (T,)
    promoted = cache["slot_of_page"][:, page_of_slot_idx] >= 0   # (B,T)
    live = (slots[None, :] < pos[:, None]) & ~promoted
    out_f, m_f, l_f = _far_stats(q, cache["far_k"], cache["far_v"], live)

    return ref.merge_attention_stats([(out_n, m_n, l_n), (out_f, m_f, l_f)])


def _near_stats(q, cache, near_len, cfg: TieredKVConfig):
    from repro.kernels.tiered_attention import near_decode_attention
    interpret = jax.default_backend() == "cpu"
    return near_decode_attention(q, cache["near_k"], cache["near_v"],
                                 near_len, interpret=interpret)


def _far_stats(q, k, v, live_mask):
    """XLA far-tier attention returning online-softmax stats.
    q: (B,H,hd); k/v: (B,T,Hkv,hd); live_mask: (B,T) bool."""
    B, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qh = q.reshape(B, Hkv, g, hd) * hd ** -0.5
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k).astype(jnp.float32)
    s = jnp.where(live_mask[:, None, None, :], s, ref.NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None]) * live_mask[:, None, None, :]
    l = p.sum(axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v)
    return (out.reshape(B, H, hd).astype(jnp.float32),
            m.reshape(B, H), l.reshape(B, H))


def page_masses(q: jax.Array, cache: dict, pos: jax.Array,
                cfg: TieredKVConfig) -> jax.Array:
    """Scoring pass: per-page attention mass with the current queries —
    the interval-sampled activation counts of the paper's BBC.

    Returns (B, n_pages) f32 normalized masses over the *whole* cache
    (near-resident pages included, so retention scores stay fresh).
    ``pos`` may be a scalar or a ragged (B,) vector."""
    B, H, hd = q.shape
    k = cache["far_k"]
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qh = q.reshape(B, Hkv, g, hd) * hd ** -0.5
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k).astype(jnp.float32)
    live = (jnp.arange(T)[None, :] < _pos_vec(pos, B)[:, None]
            )[:, None, None, :]
    s = jnp.where(live, s, ref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(live, p, 0.0)
    mass = p.sum(axis=(1, 2))                                # (B,T)
    n_pages = T // cfg.page
    return mass.reshape(B, n_pages, cfg.page).sum(-1) / max(H, 1)


def _copy_pages(near_k, near_v, far_k, far_v, rows, slots, valid, page: int):
    """IST analogue: copy up to K far pages into near slots (pure on-device
    dynamic slices; invalid plan entries are dropped)."""

    def copy_page(i, bufs):
        nk, nv = bufs
        src = jnp.where(valid[i], rows[i], 0) * page
        dst = jnp.where(valid[i], slots[i], 0) * page
        page_k = jax.lax.dynamic_slice_in_dim(far_k, src, page, 0)
        page_v = jax.lax.dynamic_slice_in_dim(far_v, src, page, 0)
        nk_new = jax.lax.dynamic_update_slice_in_dim(nk, page_k, dst, 0)
        nv_new = jax.lax.dynamic_update_slice_in_dim(nv, page_v, dst, 0)
        keep = valid[i]
        nk = jnp.where(keep, nk_new, nk)
        nv = jnp.where(keep, nv_new, nv)
        return nk, nv

    return jax.lax.fori_loop(0, rows.shape[0], copy_page, (near_k, near_v))


def plan_and_migrate(cache: dict, q: jax.Array, pos: jax.Array,
                     cfg: TieredKVConfig, idle=True,
                     masses: jax.Array | None = None) -> dict:
    """One planning interval: score -> plan -> migrate (vectorized over
    batch) under ``cfg.policy``.

    Only pages that are completely written (page_end <= pos) are candidates.
    Migration is a pure on-device copy — the IST analogue.  ``idle`` is the
    WMC gate: pass False (or a traced bool) when the serving step has no
    spare migration budget; SC/BBC ignore it, STATIC never migrates.
    ``pos`` may be a scalar or a ragged (B,) vector (the serving engine's
    slot pool — each slot's complete-page frontier is its own).
    ``masses``: optionally pass a precomputed ``page_masses(q, ...)`` result
    (callers that also need the masses for metrics avoid scoring twice).
    """
    if cfg.policy.upper() == "STATIC":
        return cache   # OS-exposed mechanism: no runtime migration, and no
                       # point paying the scoring pass for dead state
    cache = dict(cache)
    if masses is None:
        masses = page_masses(q, cache, pos, cfg)
    n_pages = masses.shape[1]
    pos_b = _pos_vec(pos, masses.shape[0])
    complete = (jnp.arange(n_pages)[None, :] + 1) * cfg.page <= pos_b[:, None]
    masses = jnp.where(complete, masses, 0.0)
    # EMA in "activations per interval" units: scale mass to a count-like
    # magnitude so TierCosts thresholds behave like the DRAM policy's.
    acts = masses * cfg.interval
    cache["scores"] = ema_update(cache["scores"], acts, cfg.costs)
    cache["last_use"] = jnp.where(acts > 0, cache["step"].astype(jnp.float32),
                                  cache["last_use"])
    cache["step"] = cache["step"] + 1

    # SC/WMC cache what received attention mass *this interval*; BBC keeps
    # its sustained-reuse eligibility over the full EMA score population.
    sc_like = cfg.policy.upper() in ("SC", "WMC")

    def per_seq(acts_row, scores, last_use, slot_of_page, page_of_slot,
                near_k, near_v, far_k, far_v):
        rows, slots, valid = plan_promotions(
            scores, slot_of_page, page_of_slot, cfg.costs,
            cfg.max_promotions, policy=cfg.policy, last_use=last_use,
            accessed=(acts_row > 0) if sc_like else None, idle=idle)
        slot_of_page, page_of_slot = apply_promotions(
            slot_of_page, page_of_slot, rows, slots, valid)
        near_k, near_v = _copy_pages(near_k, near_v, far_k, far_v, rows,
                                     slots, valid, cfg.page)
        return slot_of_page, page_of_slot, near_k, near_v, valid.sum()

    (cache["slot_of_page"], cache["page_of_slot"], cache["near_k"],
     cache["near_v"], n_migr) = jax.vmap(per_seq)(
        acts, cache["scores"], cache["last_use"], cache["slot_of_page"],
        cache["page_of_slot"], cache["near_k"], cache["near_v"],
        cache["far_k"], cache["far_v"])
    cache["migrations"] = cache["migrations"] + n_migr.sum().astype(jnp.int32)
    return cache


def preload_static_kv(cache: dict, profile_masses: jax.Array,
                      pos: jax.Array, cfg: TieredKVConfig,
                      row_mask: jax.Array | None = None) -> dict:
    """OS-exposed static placement: fill the near tier with the profile's
    hottest pages per sequence (the paper's t=0 profiling step), copying the
    pages in — then serve with ``policy="STATIC"`` (no runtime migration).

    profile_masses: (B, n_pages) profiled per-page attention mass.
    pos: current decode position (scalar or ragged (B,) vector) — only
    completely-written pages (page_end <= pos) may be pinned, else the near
    copy would contain unwritten positions that ``tiered_attention`` masks
    out of the far pass (the same guard ``plan_and_migrate`` applies).
    row_mask: optional (B,) bool — only pin these sequences, leaving the
    others' placements untouched (the serving engine pins each slot once,
    at its first planning interval after admission)."""
    cache = dict(cache)
    C = cache["page_of_slot"].shape[1]
    B, n_pages = profile_masses.shape
    pos_b = _pos_vec(pos, B)
    complete = (jnp.arange(n_pages)[None, :] + 1) * cfg.page <= pos_b[:, None]
    profile_masses = jnp.where(complete, profile_masses, 0.0)

    def per_seq(masses, near_k, near_v, far_k, far_v):
        slot_of_page, page_of_slot = preload_static(masses, C)
        slots = jnp.arange(C, dtype=jnp.int32)
        valid = page_of_slot >= 0
        rows = jnp.maximum(page_of_slot, 0)
        near_k, near_v = _copy_pages(near_k, near_v, far_k, far_v, rows,
                                     slots, valid, cfg.page)
        return slot_of_page, page_of_slot, near_k, near_v

    new_sop, new_pos_, new_nk, new_nv = jax.vmap(per_seq)(
        profile_masses, cache["near_k"], cache["near_v"], cache["far_k"],
        cache["far_v"])
    if row_mask is None:
        cache["slot_of_page"], cache["page_of_slot"] = new_sop, new_pos_
        cache["near_k"], cache["near_v"] = new_nk, new_nv
    else:
        r = row_mask
        cache["slot_of_page"] = jnp.where(r[:, None], new_sop,
                                          cache["slot_of_page"])
        cache["page_of_slot"] = jnp.where(r[:, None], new_pos_,
                                          cache["page_of_slot"])
        r4 = r[:, None, None, None]
        cache["near_k"] = jnp.where(r4, new_nk, cache["near_k"])
        cache["near_v"] = jnp.where(r4, new_nv, cache["near_v"])
    return cache
