"""Pallas kernel: two-tier embedding-row gather (TL-DRAM near segment).

The near table (hot vocabulary rows, selected by the shared BBC policy) is
small enough to pin in VMEM — the TPU analogue of the near segment.  The
kernel resolves each token against the near tier with per-row dynamic VMEM
loads; tokens that miss take their pre-gathered far-tier row (the slow HBM
gather path, produced by XLA outside the kernel).

Grid: (T / block_t,).  VMEM per step: the full near table (C x D) plus one
(block_t x D) far panel — e.g. C=1024, D=2048 bf16 => 4 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tiered_gather_kernel(slots_ref, near_ref, far_ref, o_ref, *,
                          block_t: int):
    def body(i, _):
        slot = slots_ref[i]
        near_row = near_ref[pl.ds(jnp.maximum(slot, 0), 1), :][0]
        far_row = far_ref[i, :]
        row = jnp.where(slot >= 0, near_row.astype(far_row.dtype), far_row)
        o_ref[i, :] = row
        return 0

    jax.lax.fori_loop(0, block_t, body, 0)


def tiered_gather(near_table: jax.Array, near_slots: jax.Array,
                  far_values: jax.Array, block_t: int = 256,
                  interpret: bool = False) -> jax.Array:
    """near_table: (C,D); near_slots: (T,) int32 (-1 => far); far_values: (T,D)."""
    T, D = far_values.shape
    C = near_table.shape[0]
    block_t = min(block_t, T)
    pad = (-T) % block_t
    if pad:
        near_slots = jnp.pad(near_slots, (0, pad), constant_values=-1)
        far_values = jnp.pad(far_values, ((0, pad), (0, 0)))
    Tp = T + pad

    kernel = functools.partial(_tiered_gather_kernel, block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid=(Tp // block_t,),
        in_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((C, D), lambda i: (0, 0)),
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, D), far_values.dtype),
        interpret=interpret,
    )(near_slots, near_table, far_values)
    return out[:T]
