"""Mixture-of-Experts layer: top-k routing with group-wise capacity dispatch.

The dispatch follows the GSPMD-native pattern (GShard / Switch / T5X): tokens
are partitioned into groups of ``group_size``; each group dispatches into a
per-group expert capacity C = ceil(group_size * top_k / E * capacity_factor)
via one-hot einsums.  The dispatch tensor is (G, T_g, E, C) whose size is
group_size^2 * top_k * cf per group — independent of the expert count — so
group_size is the memory knob.  Experts shard over the 'model' mesh axis (EP);
the all-to-all emerges from the dispatch einsum's sharding propagation.

Router runs in float32 (standard practice for MoE numerical stability) and
returns the Switch-style load-balancing auxiliary loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.sharding import ctx


def init_moe_params(key: jax.Array, d_model: int, cfg: MoEConfig,
                    dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_expert
    scale_in = d_model ** -0.5
    scale_out = f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, e)) * scale_in
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d_model, f)) * scale_in
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, f)) * scale_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d_model)) * scale_out
                   ).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        km = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(km[0], (d_model, fs)) * scale_in
                       ).astype(dtype),
            "w_up": (jax.random.normal(km[1], (d_model, fs)) * scale_in
                     ).astype(dtype),
            "w_down": (jax.random.normal(km[2], (fs, d_model)) * scale_out
                       ).astype(dtype),
        }
    return p


# Process-wide defaults; launchers flip these as perf knobs
# (docs/experiments.md §Perf, kimi-k2 iterations).  Dispatch-tensor traffic is
# T * group_size * top_k * cf — linear in the group size.
DEFAULT_IMPL = "einsum"
DEFAULT_GROUP_SIZE = 1024
# Capacity dropping is batch-composition-dependent (a real property of
# capacity-based MoE serving); tests flip this to make paths comparable.
DEFAULT_NO_DROP = False


def moe_block(params: dict, x: jax.Array, cfg: MoEConfig,
              group_size: int | None = None,
              no_drop: bool | None = None,
              impl: str | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).

    Tokens are grouped along the flattened (B*S) axis; groups inherit the
    batch sharding, experts the model sharding.  ``no_drop=True`` sizes the
    capacity for the worst case (decode paths, where dropping a token would
    corrupt generation).

    impl='einsum': the classic GSPMD one-hot dispatch.  Its dispatch tensor
    costs T*g*top_k*cf bytes of traffic — quadratic in the group size and
    the dominant cost for large-E MoE (measured: ~80% of kimi-k2's wire
    bytes).  impl='gather': scatter/gather dispatch (MegaBlocks/MaxText
    family) — builds (E, C) index maps from the same capacity assignment and
    moves only the gathered rows.  Identical semantics including dropping.
    """
    impl = impl or DEFAULT_IMPL
    group_size = group_size or DEFAULT_GROUP_SIZE
    if no_drop is None:
        no_drop = DEFAULT_NO_DROP
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    g = min(group_size, T)
    while T % g:                      # group size must divide token count
        g //= 2
    G = T // g
    if no_drop:
        C = g                         # worst case: every token, same expert
    else:
        C = max(1, int(g * K / E * cfg.capacity_factor))

    xg = x.reshape(G, g, D)
    xg = ctx.constrain(xg, ctx.BATCH, None, None)   # groups follow batch DP

    # --- router (f32) ---
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])                       # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, expert_idx = jax.lax.top_k(probs, K)               # (G,g,K)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # Switch load-balance loss: E * sum_e f_e * p_e.
    density = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * mean_prob)

    # --- capacity assignment: position of each (token, choice) in its expert.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)     # (G,g,K,E)
    flat = onehot.reshape(G, g * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (G,g*K,E)
    pos = (pos * flat).sum(-1).reshape(G, g, K)                 # (G,g,K)
    keep = (pos < C)
    weights = weights * keep

    if impl == "gather":
        # --- scatter/gather dispatch: move rows, not one-hot tensors ---
        # token slot index per (expert, capacity): T*K int32 scatters.
        flat_t = jnp.broadcast_to(
            jnp.arange(g, dtype=jnp.int32)[None, :, None], (G, g, K))
        e_idx = expert_idx.astype(jnp.int32)
        # route invalid (dropped) updates out of bounds -> dropped
        scatter_e = jnp.where(keep, e_idx, E)
        scatter_c = jnp.where(keep, pos, C)
        index_map = jnp.full((G, E, C), g, jnp.int32)           # g == "none"
        index_map = jax.vmap(
            lambda im, se, sc, ft: im.at[se.reshape(-1), sc.reshape(-1)]
            .set(ft.reshape(-1), mode="drop"))(
                index_map, scatter_e, scatter_c, flat_t)

        xg_pad = jnp.concatenate(
            [xg, jnp.zeros((G, 1, D), xg.dtype)], axis=1)       # row g = zeros
        expert_in = jax.vmap(lambda xp, im: jnp.take(xp, im, axis=0))(
            xg_pad, index_map)                                  # (G,E,C,D)
        expert_in = ctx.constrain(expert_in, ctx.BATCH, ctx.MODEL, None,
                                  None)
        gate = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
        act = jax.nn.silu(gate) * up
        expert_out = jnp.einsum("gecf,efd->gecd", act, params["w_down"])

        # combine: each token gathers its K expert rows back.  Dropped
        # entries read an arbitrary row but carry zero weight.
        flat_ec = (jnp.where(keep, e_idx, E - 1) * C
                   + jnp.where(keep, pos, C - 1))               # (G,g,K)
        out_rows = jax.vmap(lambda eo, idx: jnp.take(eo, idx, axis=0))(
            expert_out.reshape(G, E * C, D),
            flat_ec.reshape(G, g * K)).reshape(G, g, K, D)
        y = jnp.einsum("gtkd,gtk->gtd", out_rows,
                       weights.astype(x.dtype)).reshape(B, S, D)
    else:
        # --- dispatch / combine one-hot tensors (bf16, the GSPMD pattern).
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C,
                                dtype=x.dtype)                  # (G,g,K,C)
        exp_oh = onehot.astype(x.dtype)                         # (G,g,K,E)
        dispatch = jnp.einsum("gtke,gtkc->gtec", exp_oh,
                              pos_oh)                           # (G,g,E,C)
        # per-choice weights: contract k jointly with both one-hots (a plain
        # dispatch*Sum_k(w) would weight every choice by 1.0 — bug caught by
        # the gather-impl equivalence test)
        combine = jnp.einsum("gtke,gtkc,gtk->gtec", exp_oh, pos_oh,
                             weights.astype(x.dtype))

        # --- expert FFN ---  (EP: the E dim pins to the 'model' axis; the
        # dispatch einsum's resharding is the all-to-all)
        expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)  # (G,E,C,D)
        expert_in = ctx.constrain(expert_in, ctx.BATCH, ctx.MODEL, None,
                                  None)
        gate = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
        act = jax.nn.silu(gate) * up
        expert_out = jnp.einsum("gecf,efd->gecd", act, params["w_down"])

        y = jnp.einsum("gtec,gecd->gtd", combine,
                       expert_out).reshape(B, S, D)

    if "shared" in params:
        sp = params["shared"]
        gsh = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
        ush = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gsh) * ush, sp["w_down"])

    return y.astype(x.dtype), aux
