"""Continuous-batching tiered-KV serving engine (the TL-DRAM runtime).

The paper's near segment only pays off when many concurrent accesses share
the fast path; the serving analogue is a *slot pool*: a fixed batch of
decode slots that independent sequences are admitted into and retired from,
so one batched decode step serves every in-flight sequence at once (ragged
``pos`` — each slot sits at its own position).

Since ISSUE 5 the **page pool is the single source of truth** for KV bytes
(docs/design.md §2f).  The TL-DRAM near segment is not a duplicate of far
rows — the isolation transistor splits one bitline so the same array serves
both tiers — and the serving stack now honors that: there is no dense
per-slot KV master.  Prefill scatters straight into allocated pool pages,
decode appends write through the page table (``paged_step_metadata``'s
append routing), and scoring / planning / pinning / the verification probe
all read the pool.  Each slot maps only the pages its request can ever
touch (``ceil((S + max_new - 1)/page)``), so live KV bytes track demand,
not slot capacity — ``ServingReport.kv_bytes_live`` vs
``kv_bytes_dense_equiv`` pins the ratio.

A radix prefix cache (``serve.prefix``) lets admissions reuse
already-written pages for shared prompt prefixes — refcount++, prefill
**only the suffix** (the modeled clock and the real compute both drop), and
the suffix-chunked prefill reproduces the full-prefill cache rows
bit-identically.  The near tier is global: a hot shared page is scored by
the aggregate attention mass of every referencing sequence and promoted
ONCE for all tenants — the paper's one-IST-many-accesses economics.

Scheduler loop (``ServingEngine.run``; docs/design.md §2g for the
ISSUE 8 overlapped-tick pipeline):

  admit    : pop arrived requests into free slots — match the prompt
             against the radix prefix cache, map shared pages, and either
             prefill the suffix straight into fresh pool pages (one
             jitted program) and seed the first token (synchronous mode),
             or, with ``prefill_chunk_tokens`` set, enqueue a
             ``_PrefillJob`` — pages allocated now, NO prefill compute:
             the prompt fills in over later ticks' chunk budgets.
  prefill  : (chunked mode) advance pending jobs FIFO up to the tick
             budget (halved while active slots exceed half the pool),
             each job resuming from its saved cursor into its own pages
             via the chunk-resume step — bit-identical rows to a one-shot
             prefill.  Chunk tokens piggyback on the decode tick's cost
             (per-token only, no second step_overhead); a pending slot's
             device page-table row stays -1, so the decode lane treats it
             exactly like a free slot until the job completes.
  decode   : ONE batched ``transformer.paged_decode_step`` with per-slot
             ``pos`` emits a token for every active slot, appending K/V
             through the page table into the pool — via the fused
             page-table-walking kernel (``tier.fused_kernel``) or the
             materializing oracle path (bit-identical logits to the
             retired PR-4 dense-master path).
  maintain : every ``tier.interval`` decode steps, score per-page
             attention mass with the step's layer-0 queries
             (pool-natively — the fused mode scores through
             `kernels.paged_masses`, no far-view gather), aggregate it
             onto pool pages, and run the configured policy (SC/WMC/BBC
             via ``paged_plan_and_migrate``; STATIC pins each slot once
             at its first interval) — the amortized IST.  The pass is
             cost-aware for ALL policies: while the run queue is hot
             (pending chunks or waiting arrivals) it defers, at most
             ``defer_limit`` passes in a row.  Mapping changes re-derive
             the per-layer near buffers from the pool
             (``refresh_near_from_pool``); with ``overlap_migration`` the
             copies land in a shadow buffer swapped at the next tick
             boundary, and migration bytes bill a background lane that
             stalls the clock only when saturated.
  retire   : finished sequences release their page refs; pages at refcount
             zero are freed unless the prefix cache retains them for
             re-arrivals.  At run end a refcount sweep asserts ZERO
             orphaned pages (every page free, referenced-by-nobody, or
             trie-retained — and nothing else).

The decode path is *exact* (full-live-prefix attention in both read
modes), so emitted tokens match the single-sequence ``greedy_generate``
reference bit-for-bit with sharing on or off (tests/test_prefix_sharing.py,
tests/test_serving_engine.py); the paged tiered state drives the byte-cost
model and, optionally, a pool-native read-path verification probe
(``verify_tiered_read``).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import tiered_kv as tkv
from repro.core.tiered_kv import PagePool, TieredKVConfig
from repro.kernels import ref
from repro.models import transformer
from repro.serve.metrics import CostModel, ServingReport, merge_lane_reports
from repro.sharding.specs import cache_specs, kv_shard_count, to_named
from repro.serve.prefix import RadixPrefixCache
from repro.serve.trace import Request

# the mapping-only tier-state leaves the engine owns (pool/near buffers are
# separate per-layer arrays — the ownership inversion)
_TIER_KEYS = ("page_table", "slot_of_page", "page_of_slot", "scores",
              "last_use", "step", "migrations")


@dataclass
class ServingConfig:
    n_slots: int = 4
    max_len: int = 256
    prefill_bucket: int = 32      # prompt lengths pad up to a multiple of
                                  # this (bounds jit recompiles; exact —
                                  # causal attention ignores the pad tail)
    tier: TieredKVConfig = field(default_factory=TieredKVConfig)
    cost: CostModel = field(default_factory=CostModel)
    share_prefix: bool = False    # radix prefix cache over the page pool:
                                  # admissions reuse shared prompt pages and
                                  # prefill only the suffix
    pool_pages: int | None = None  # far-pool capacity; default covers every
                                   # slot fully plus retention slack for the
                                   # prefix cache
    verify_tiered_read: bool = False   # probe paged tiered read vs
                                       # attention over the materialized
                                       # pool view at every planning pass
    # -- overlap knobs (ISSUE 8 tentpole) ------------------------------------
    prefill_chunk_tokens: int | None = None
                                  # budget of admission-prefill tokens run
                                  # per tick, interleaved with the batched
                                  # decode step (Sarathi-style chunked
                                  # prefill).  None = legacy synchronous
                                  # admission: the whole prompt prefills
                                  # inside the admitting tick and every
                                  # in-flight request stalls behind it.
    overlap_migration: bool = False
                                  # charge migration bytes to a background
                                  # lane that only adds latency when
                                  # saturated, and double-buffer the near
                                  # tier (promotion copies land in a shadow
                                  # buffer, swapped at the tick boundary)
    defer_limit: int = 2          # cost-aware deferral gate (the WMC
                                  # queue-idle gate generalized to all four
                                  # policies): consecutive planning passes
                                  # skippable while the run queue is hot


@dataclass
class _Slot:
    req: Request
    emitted: list
    last_emit: float              # modeled clock of the last emitted token


@dataclass
class _PrefillJob:
    """A chunk-resumable admission prefill in flight: pages are allocated
    (and refcounted — real bytes) up front, the cursor tracks prompt rows
    already written to the pool, and the slot's device page table stays
    unmapped until completion so decode appends sentinel-drop and
    scoring/planning ignore the slot, exactly like a free one."""
    req: Request
    prompt: np.ndarray            # (S,) int32
    S: int
    row: list                     # full page mapping (matched + fresh)
    n_need: int
    matched: int                  # prompt tokens served by the prefix cache
    cursor: int                   # prompt rows already written to the pool


class ServingEngine:
    def __init__(self, params, arch: ArchConfig, cfg: ServingConfig):
        assert arch.n_heads and arch.ssm is None, \
            "serving engine requires an attention-family architecture"
        assert not arch.sliding_window, \
            "ragged slot pool + ring buffer not supported yet"
        assert cfg.max_len % cfg.tier.page == 0, \
            "max_len must be a page multiple"
        assert not (cfg.share_prefix and arch.mrope), \
            "prefix sharing needs 1-D positions"
        self.params, self.arch, self.cfg = params, arch, cfg
        self.n_pages = cfg.max_len // cfg.tier.page
        tier_cfg = cfg.tier
        # fused mode (ISSUE 4/5): reads walk the page table in-kernel and
        # scoring runs the pool-native mass kernel; the non-fused mode
        # materializes per-layer far views from the SAME pool (the oracle)
        self.fused = bool(tier_cfg.fused_kernel)
        # mesh-native serving (docs/design.md §2h): with tier.mesh set and
        # Hkv divisible by the 'model' axis, the pool/near buffers are
        # KV-head-sharded and every device streams 1/kv_shards of the KV
        # bytes per decode step (the cost model's kv_shards lane).  The
        # GQA/MQA fallback (kv_shards == 1) keeps everything replicated.
        self.mesh = tier_cfg.mesh
        self.kv_shards = kv_shard_count(self.mesh, arch.n_kv_heads)
        # Pool sizing: worst case (no sharing) every slot maps private
        # pages; the slack keeps retired prompts cached for re-arrivals.
        self.pool_pages = cfg.pool_pages if cfg.pool_pages is not None \
            else (cfg.n_slots + 4) * self.n_pages
        assert self.pool_pages >= cfg.n_slots * self.n_pages, \
            "pool must at least cover the slot pool"
        P = self.pool_pages

        if cfg.prefill_chunk_tokens is not None:
            assert cfg.prefill_chunk_tokens >= tier_cfg.page, \
                "prefill_chunk_tokens must cover at least one page"

        from repro.launch.serve import (make_paged_tiered_decode_step,
                                        make_pool_chunk_prefill_step,
                                        make_pool_prefill_step,
                                        make_pool_suffix_prefill_step)
        self._decode = jax.jit(make_paged_tiered_decode_step(arch, tier_cfg))
        # per-step read metadata, computed ONCE per tick and shared by
        # every layer: lengths = pos + 1 (the appended token is live),
        # append routing from pos
        self._meta = jax.jit(
            lambda tier, pos: tkv.paged_step_metadata(
                tier, pos + 1, tier_cfg, append_pos=pos, pool_pages=P))

        def _view(tier, pk, pv, nk, nv):
            """Single-layer tiered_kv view over layer 0 of the per-layer
            buffers (sliced inside jit: lazy, and unused slices are DCE'd).
            Layer 0 is representative — every layer shares one page table
            and one near mapping; the scoring query is layer 0's."""
            return {**tier, "pool_k": pk[0], "pool_v": pv[0],
                    "near_k": nk[0], "near_v": nv[0]}

        def _plan_fn(tier, pk, pv, nk, nv, q, pos, idle, m):
            new = tkv.paged_plan_and_migrate(
                _view(tier, pk, pv, nk, nv), q, pos, tier_cfg, idle=idle,
                masses=m)
            return {k: new[k] for k in _TIER_KEYS}

        self._plan = jax.jit(_plan_fn)
        self._masses = jax.jit(
            lambda q, tier, pk, pv, pos: tkv.paged_page_masses(
                q, {**tier, "pool_k": pk[0], "pool_v": pv[0]}, pos,
                tier_cfg))

        probe_cfg = dataclasses.replace(tier_cfg, gather_kernel=False,
                                        fused_kernel=False)

        def _probe_fn(tier, pk, pv, nk, nv, q, pos):
            view = _view(tier, pk, pv, nk, nv)
            got = tkv.paged_tiered_attention(view, q, pos, tier_cfg)
            far_k, far_v = tkv.paged_far_view(view, probe_cfg)
            want = ref.decode_attention_ref(q[:, None], far_k, far_v,
                                            pos)[:, 0]
            return got, want

        self._probe = jax.jit(_probe_fn)
        self._sync_near = jax.jit(tkv.refresh_near_from_pool)
        # jax.jit caches per input shape, so one wrapper covers every
        # prompt-length bucket (and every matched-prefix length)
        self._prefill = jax.jit(
            make_pool_prefill_step(arch, cfg.max_len, tier_cfg.page,
                                   mesh=self.mesh))
        self._prefill_sfx = jax.jit(
            make_pool_suffix_prefill_step(arch, cfg.max_len, tier_cfg.page,
                                          mesh=self.mesh))
        # chunk-resumable admission prefill: t_pre (the cursor) is static —
        # it sizes the in-jit prefix slice; jit caches per (t_pre, s_pad)
        self._prefill_chunk = jax.jit(
            make_pool_chunk_prefill_step(arch, cfg.max_len, tier_cfg.page,
                                         mesh=self.mesh),
            static_argnames=("t_pre",))
        page = tier_cfg.page

        def gather_prefix(pool_k, pool_v, ids):
            """(L,P,page,Hkv,hd) pools + (m,) ids -> (L,1,m*page,Hkv,hd)."""
            k = pool_k[:, ids]
            L, m, _, Hkv, hd = k.shape
            return (k.reshape(L, 1, m * page, Hkv, hd),
                    pool_v[:, ids].reshape(L, 1, m * page, Hkv, hd))

        self._gather_prefix = jax.jit(gather_prefix)

    # -- admission ----------------------------------------------------------

    def _map_request(self, req: Request):
        """The mapping steps shared by both admission paths.

        1. prefix match: reuse already-written pool pages (refcount++).
           match() caps at (S-1)//page pages <= n_need - 1, so at least
           one fresh page always remains for the suffix.
        2. map ONLY the pages this request can ever touch onto fresh pages
           (evicting LRU cached-idle pages under pressure; their tier
           state resets): prefill writes [0, S), decode appends reach at
           most S + max_new - 2 (the final emitted token is never
           appended) — live KV bytes track demand."""
        cfg = self.cfg
        page = cfg.tier.page
        prompt = np.asarray(req.prompt, np.int32)
        S = int(prompt.shape[0])
        assert S + req.max_new_tokens <= cfg.max_len, \
            f"request {req.rid} does not fit max_len={cfg.max_len}"
        n_need = max(1, -(-(S + req.max_new_tokens - 1) // page))
        matched_ids = [] if self.prefix is None \
            else self.prefix.match(prompt)
        m = len(matched_ids)
        if m:
            self.pool.acquire(matched_ids)
        if self.prefix is not None:
            fresh, evicted = self.prefix.allocate(n_need - m)
            if evicted:
                self.tier = tkv.paged_release_pages(self.tier, evicted,
                                                    cfg.tier)
                self._after_mapping_change()   # eviction compacts the near
                                               # mapping: shadow is stale
        else:
            fresh = self.pool.allocate(n_need - m)
        return prompt, S, n_need, matched_ids + fresh, m

    def _admit(self, req: Request, slot: int, clock: float) -> float:
        cfg = self.cfg
        page = cfg.tier.page
        prompt, S, n_need, row, m = self._map_request(req)
        matched = m * page
        self.pt_host[slot] = -1
        self.pt_host[slot, :n_need] = row
        self.tier["page_table"] = self.tier["page_table"].at[slot].set(
            jnp.asarray(self.pt_host[slot], jnp.int32))

        # 3. prefill ONLY the suffix (bucket-padded) STRAIGHT INTO the
        #    slot's fresh pool pages — one jitted program; the dense rows
        #    are a transient inside it.  Shared-prefix K/V comes from the
        #    pool; real compute drops with ``matched``.
        s_len = S - matched
        s_pad = -(-s_len // cfg.prefill_bucket) * cfg.prefill_bucket
        padded = np.zeros((1, s_pad), np.int32)
        padded[0, :s_len] = prompt[matched:]
        ids = -np.ones(self.n_pages, np.int32)
        ids[m:n_need] = row[m:]
        ids = jnp.asarray(ids)
        if m:
            kpre, vpre = self._gather_prefix(
                self.pool_k, self.pool_v,
                jnp.asarray(row[:m], jnp.int32))
            positions = matched + np.arange(s_pad, dtype=np.int32)[None]
            logits, self.pool_k, self.pool_v = self._prefill_sfx(
                self.params, {"tokens": padded, "positions": positions},
                kpre, vpre, self.pool_k, self.pool_v, ids)
        else:
            logits, self.pool_k, self.pool_v = self._prefill(
                self.params, {"tokens": padded}, self.pool_k, self.pool_v,
                ids)
        first = int(jnp.argmax(logits[0, s_len - 1]))

        # 4. index the prompt's new full pages for later sharers — they are
        #    already in the pool (prefill wrote them); no re-gather
        if self.prefix is not None:
            n_full = S // page
            if n_full > m:
                self.prefix.insert(prompt[:n_full * page], row[:n_full])
        self._after_mapping_change()

        self.pos[slot] = S
        self.tok[slot] = first
        self._static_pinned[slot] = False
        clock += cfg.cost.prefill_cost(s_len)
        self.slots[slot] = _Slot(req=req, emitted=[first], last_emit=clock)
        ttft = clock - self._visible_clock[req.rid]
        self.report.token_latencies.append(ttft)
        self.report.ttfts.append(ttft)
        self.report.tokens += 1
        self.report.prefill_tokens += s_len
        self.report.prefill_tokens_full += S
        self.report.prefix_hit_tokens += matched
        self.slot_history.setdefault(slot, []).append(req.rid)
        return clock

    # -- chunked admission (ISSUE 8 tentpole) --------------------------------

    def _admit_chunked(self, req: Request, slot: int):
        """Reserve the slot and map the request's pages NOW (cheap,
        host-side), but run NO prefill compute: the prompt fills in over
        the next ticks' chunk budgets, overlapped with decode.  The device
        page table stays unmapped until completion."""
        prompt, S, n_need, row, m = self._map_request(req)
        matched = m * self.cfg.tier.page
        self.pending[slot] = _PrefillJob(req=req, prompt=prompt, S=S,
                                         row=row, n_need=n_need,
                                         matched=matched, cursor=matched)
        self.report.prefill_tokens_full += S
        self.report.prefix_hit_tokens += matched
        self.slot_history.setdefault(slot, []).append(req.rid)

    def _prefill_budget(self, n_active: int) -> int:
        """This tick's chunk budget, shrunk by the cost-aware gate when the
        tick is decode-heavy: serving more than half the slot pool halves
        the prefill lane so admission work cannot crowd out in-flight
        inter-token latency (floor: one page)."""
        budget = self.cfg.prefill_chunk_tokens
        if n_active > self.cfg.n_slots // 2:
            budget = max(self.cfg.tier.page, budget // 2)
        return budget

    def _advance_prefills(self, budget: int) -> tuple[int, list]:
        """Run at most ``budget`` prompt tokens of pending admission
        prefills, FIFO, each job resuming from its saved cursor into its
        already-allocated pool pages.  The boundary page of a mid-page
        cursor is rewritten whole — an identity for rows below the cursor
        (the chunk step's prefix rows ARE the pool bytes), so coverage
        grows monotonically and the final rows are bit-identical to a
        one-shot prefill.  Completed full pages are trie-inserted
        immediately, so arrivals overlapping a still-chunking prompt can
        already share it.  Returns (chunk_tokens, completions) with
        completions = [(slot, job, first_token)] for jobs reaching S."""
        cfg = self.cfg
        page = cfg.tier.page
        chunk_toks, done = 0, []
        for slot, job in list(self.pending.items()):
            take = min(budget - chunk_toks, job.S - job.cursor)
            if take <= 0:
                break                       # FIFO: no skipping ahead
            c0, n = job.cursor, take
            s_pad = -(-n // cfg.prefill_bucket) * cfg.prefill_bucket
            padded = np.zeros((1, s_pad), np.int32)
            padded[0, :n] = job.prompt[c0:c0 + n]
            p_lo = c0 // page               # first page not yet complete
            p_hi = min(-(-(c0 + n) // page), job.n_need)
            ids = -np.ones(self.n_pages, np.int32)
            ids[p_lo:p_hi] = job.row[p_lo:p_hi]
            ids = jnp.asarray(ids)
            if c0 == 0:
                logits, self.pool_k, self.pool_v = self._prefill(
                    self.params, {"tokens": padded}, self.pool_k,
                    self.pool_v, ids)
            else:
                positions = c0 + np.arange(s_pad, dtype=np.int32)[None]
                prefix_ids = jnp.asarray(job.row[:-(-c0 // page)], jnp.int32)
                logits, self.pool_k, self.pool_v = self._prefill_chunk(
                    self.params, {"tokens": padded, "positions": positions},
                    self.pool_k, self.pool_v, prefix_ids, ids, t_pre=c0)
            job.cursor += n
            chunk_toks += n
            self.report.prefill_tokens += n
            self.report.prefill_chunks += 1
            if self.prefix is not None:
                n_full = job.cursor // page
                if n_full > job.matched // page:
                    self.prefix.insert(job.prompt[:n_full * page],
                                       job.row[:n_full])
            if job.cursor >= job.S:
                done.append((slot, job, int(jnp.argmax(logits[0, n - 1]))))
                del self.pending[slot]
        return chunk_toks, done

    def _complete_prefill(self, slot: int, job: _PrefillJob, first: int,
                          clock: float):
        """Install a finished prompt: the page table goes live (decode
        appends route through it from the next tick), the slot activates,
        and the final chunk's last-row logits seed the first token.  TTFT
        is the clock at the completing tick minus the request's visible
        arrival — queueing plus chunked prefill."""
        self.pt_host[slot] = -1
        self.pt_host[slot, :job.n_need] = job.row
        self.tier["page_table"] = self.tier["page_table"].at[slot].set(
            jnp.asarray(self.pt_host[slot], jnp.int32))
        self._after_mapping_change()
        self.pos[slot] = job.S
        self.tok[slot] = first
        self._static_pinned[slot] = False
        self.slots[slot] = _Slot(req=job.req, emitted=[first],
                                 last_emit=clock)
        ttft = clock - self._visible_clock[job.req.rid]
        self.report.token_latencies.append(ttft)
        self.report.ttfts.append(ttft)
        self.report.tokens += 1

    def _retire(self, slot: int):
        st = self.slots[slot]
        self.report.outputs[st.req.rid] = list(st.emitted)
        self.slots[slot] = None
        self.pos[slot] = 0
        self.tok[slot] = 0
        self._near_tokens[slot] = 0
        # drop this slot's page references NOW, not at the next admit: freed
        # pages' decayed scores would otherwise stay promotion-eligible and
        # keep the planning pass migrating (and billing) stale pages.
        # Prefix-cached pages survive at refcount zero (re-arrival hits) —
        # including their near-tier residency.
        pids = [int(p) for p in self.pt_host[slot] if p >= 0]
        freed = self.pool.release(pids)
        if freed:
            self.tier = tkv.paged_release_pages(self.tier, freed,
                                                self.cfg.tier)
        self.pt_host[slot] = -1
        self.tier["page_table"] = self.tier["page_table"].at[slot].set(-1)
        self._after_mapping_change()
        self.free.append(slot)
        self.free.sort()

    # -- pool-native bookkeeping ---------------------------------------------

    def _after_mapping_change(self):
        """Mark the per-layer near buffers / host residency mirror stale
        after any event that moves the global near mapping or the page
        tables (plan / pin / release / admit / retire).  The actual re-sync
        happens once per tick (``_flush_mapping``) — N retires + M admits
        in one tick cost one gather, not N+M.  Any such event also
        invalidates the shadow near buffer (it was derived from the
        previous mapping)."""
        self._mapping_dirty = True
        self._shadow_near = None

    def _flush_mapping(self):
        if not self._mapping_dirty:
            return
        if self._shadow_near is not None:
            # double-buffered near tier (ISSUE 8): the promotion copies
            # were dispatched right after planning and drained behind the
            # tick's host work — swap at the tick boundary instead of
            # re-gathering on the critical path.  The shadow stayed valid
            # because only COMPLETE pages promote: decode appends and
            # prefill chunks never write a near-resident page.
            self.near_k, self.near_v = self._shadow_near
            self._shadow_near = None
        else:
            self.near_k, self.near_v = self._sync_near(
                self.pool_k, self.pool_v, self.tier["page_of_slot"])
        sop = np.asarray(self.tier["slot_of_page"])
        self._promoted_host = (self.pt_host >= 0) \
            & (sop[np.maximum(self.pt_host, 0)] >= 0)
        self._near_used = int(
            (np.asarray(self.tier["page_of_slot"]) >= 0).sum())
        self._mapping_dirty = False

    def _far_rows_shadow(self) -> int:
        """Host-side recomputation of the fused step's far rows touched:
        per slot, the live rows of its mapped, non-promoted pages (lengths
        = pos + 1: the token appended this step is attended)."""
        lengths = self.pos + 1
        page_start = np.arange(self.n_pages) * self.cfg.tier.page
        live = np.clip(lengths[:, None] - page_start[None, :], 0,
                       self.cfg.tier.page)
        walk = (self.pt_host >= 0) & ~self._promoted_host
        return int((live * walk).sum())

    def _account_kv_bytes(self):
        """Track peak LIVE KV bytes: referenced pool pages ONLY, across all
        layers, K and V.  The near tier holds *derived copies* of pool
        bytes (TL-DRAM's near segment is the same mat behind the isolation
        transistor, not extra capacity) — accounted in ``kv_bytes_near``,
        never against the dense-equiv denominator, which never included a
        near tier either (the kv_live_ratio 1.042 bench lie, ISSUE 8).
        Trie-retained idle pages are reclaimable cache
        (``kv_bytes_cached``).

        ``live <= dense_equiv`` is an engine invariant asserted every
        tick: each slot (or pending prefill job) maps at most
        ``ceil((S + max_new - 1)/page) <= n_pages`` pages and shared pages
        are counted once."""
        item = self.pool_k.dtype.itemsize
        row = self.arch.n_kv_heads * self.arch.resolved_head_dim * item * 2
        L = self.arch.n_layers
        page = self.cfg.tier.page
        ref_pages = int((self.pool.refcount > 0).sum())
        live = L * ref_pages * page * row
        assert live <= self.report.kv_bytes_dense_equiv, (
            f"kv_live invariant violated: {live} referenced-pool bytes > "
            f"dense-equiv {self.report.kv_bytes_dense_equiv} "
            f"({ref_pages} pages referenced)")
        cached = int(((self.pool.refcount == 0) & self.pool.cached).sum())
        self.report.kv_bytes_live = max(self.report.kv_bytes_live, live)
        self.report.kv_bytes_near = max(
            self.report.kv_bytes_near, L * self._near_used * page * row)
        self.report.kv_bytes_cached = max(self.report.kv_bytes_cached,
                                          L * cached * page * row)

    def _assert_zero_orphans(self):
        """Refcount sweep at engine shutdown (ISSUE 5 satellite): with all
        sequences retired, every pool page must be free, or retained by the
        prefix trie — anything still referenced (or cached outside the
        trie) is an orphan the release path leaked."""
        leaked = np.flatnonzero(self.pool.refcount > 0)
        if leaked.size:
            raise RuntimeError(
                f"orphaned pool pages at shutdown (refcount > 0 with no "
                f"live slot): {leaked.tolist()}")
        cached = set(np.flatnonzero(self.pool.cached).tolist())
        trie = set() if self.prefix is None else self.prefix.cached_pages()
        if cached != trie:
            raise RuntimeError(
                f"retention flags diverge from the prefix trie: "
                f"cached-not-in-trie {sorted(cached - trie)}, "
                f"trie-not-cached {sorted(trie - cached)}")
        free = set(int(p) for p in self.pool._free)
        if (free | cached) != set(range(self.pool_pages)) or (free & cached):
            raise RuntimeError("free list + trie retention do not "
                               "partition the pool at shutdown")

    # -- background tier maintenance ----------------------------------------

    def _bill_migration(self, clock: float, pages_moved: int) -> float:
        """Charge migration bytes to the modeled clock.  Synchronous mode:
        the decode clock pays immediately (the pre-ISSUE-8 stall).
        Overlapped mode: the copies drain on a background lane — the clock
        stalls only while the lane is still busy with the previous batch
        (saturation), then the lane stays busy for this batch's cost."""
        cost = self.cfg.cost.migration_cost(pages_moved, self.cfg.tier.page)
        if not self.cfg.overlap_migration:
            return clock + cost
        stall = max(0.0, self._lane_free - clock)
        self.report.migration_stall += stall
        clock += stall
        self._lane_free = clock + cost
        return clock

    def _pin_static(self, masses: np.ndarray, need: np.ndarray,
                    clock: float) -> float:
        """STATIC: at a slot's first planning interval, place its hottest
        complete pages into FREE global near slots (profile placement — no
        later migration, no eviction of earlier pins)."""
        cfg = self.cfg
        tier = cfg.tier
        ros = np.asarray(self.tier["page_of_slot"])
        sop = np.asarray(self.tier["slot_of_page"])
        free_slots = [c for c in range(ros.shape[0]) if ros[c] < 0]
        complete = ((np.arange(self.n_pages)[None, :] + 1) * tier.page
                    <= self.pos[:, None])
        cand_mass: dict[int, float] = {}
        for b in np.flatnonzero(need):
            for j in range(self.n_pages):
                p = int(self.pt_host[b, j])
                if p >= 0 and complete[b, j] and masses[b, j] > 0 \
                        and sop[p] < 0:
                    cand_mass[p] = cand_mass.get(p, 0.0) + float(masses[b, j])
        ranked = sorted(cand_mass, key=lambda p: -cand_mass[p])
        chosen = ranked[:len(free_slots)]
        if chosen:
            self.tier = tkv.paged_pin_pages(self.tier, chosen,
                                            free_slots[:len(chosen)], tier)
            clock = self._bill_migration(clock, len(chosen))
            self.report.migrations += len(chosen)  # pin copies are ISTs too
        self._static_pinned |= need
        return clock

    def _maintain(self, q0, clock: float, idle: bool) -> float:
        cfg = self.cfg
        tier = cfg.tier
        active = np.array([s is not None for s in self.slots])
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        # one scoring pass per interval, straight off the pool (fused mode:
        # the pool-native mass kernel — no far-view gather); the same
        # per-slot masses drive planning/pinning AND the hit-mass metric
        masses_dev = self._masses(q0, self.tier, self.pool_k, self.pool_v,
                                  pos_vec)
        if tier.policy.upper() == "STATIC":
            need = active & ~self._static_pinned
            if need.any():
                clock = self._pin_static(np.asarray(masses_dev), need, clock)
                self._after_mapping_change()
        else:
            before = int(self.tier["migrations"])
            self.tier = self._plan(self.tier, self.pool_k, self.pool_v,
                                   self.near_k, self.near_v, q0, pos_vec,
                                   idle, masses_dev)
            moved = int(self.tier["migrations"]) - before
            clock = self._bill_migration(clock, moved)
            self.report.migrations += moved
            if moved:     # mapping unchanged when nothing migrated
                self._after_mapping_change()
        if self._mapping_dirty:
            # dispatch the near re-derivation NOW (async): the scatter runs
            # behind this tick's emit/retire host work, and _flush_mapping
            # swaps it in at the next tick boundary — the double buffer
            self._shadow_near = self._sync_near(
                self.pool_k, self.pool_v, self.tier["page_of_slot"])
        sop = np.asarray(self.tier["slot_of_page"])
        promoted = (self.pt_host >= 0) & (sop[np.maximum(self.pt_host, 0)]
                                          >= 0)              # (B, n_pages)
        self._near_tokens = promoted.sum(axis=1) * tier.page
        # near-tier hit mass over active slots (the paper's near-segment hit
        # rate, in attention-mass units) — a shared promoted page counts for
        # every referencing slot: one IST, many accesses
        if active.any():
            masses = np.asarray(masses_dev)
            tot = masses[active].sum()
            if tot > 0:
                self.report.near_hit_mass.append(
                    float((masses * promoted)[active].sum() / tot))
            if cfg.verify_tiered_read:
                self._flush_mapping()   # the probe reads the near buffers
                got, want = self._probe(self.tier, self.pool_k, self.pool_v,
                                        self.near_k, self.near_v, q0,
                                        pos_vec)
                err = float(jnp.max(jnp.abs(
                    (got - want)[jnp.asarray(active)])))
                self.report.max_read_err = max(self.report.max_read_err, err)
        return clock

    # -- driver --------------------------------------------------------------

    def run(self, trace: list[Request], scenario: str = "trace") -> ServingReport:
        """Replay an offline arrival trace to completion."""
        cfg = self.cfg
        arch = self.arch
        self.report = ServingReport(scenario=scenario,
                                    policy=cfg.tier.policy,
                                    n_requests=len(trace))
        hd = arch.resolved_head_dim
        dtype = jnp.bfloat16
        # THE KV store: per-layer shared page pool + per-layer global near
        # buffer.  No dense per-slot master exists anywhere in this engine.
        pshape = (arch.n_layers, self.pool_pages, cfg.tier.page,
                  arch.n_kv_heads, hd)
        self.pool_k = jnp.zeros(pshape, dtype)
        self.pool_v = jnp.zeros(pshape, dtype)
        nshape = (arch.n_layers, cfg.tier.near_pages * cfg.tier.page,
                  arch.n_kv_heads, hd)
        self.near_k = jnp.zeros(nshape, dtype)
        self.near_v = jnp.zeros(nshape, dtype)
        if self.kv_shards > 1:
            # place the pool/near buffers on their KV-head sharding up
            # front, so every jitted step consumes and produces the sharded
            # layout instead of re-sharding on entry
            kv_tree = {"pool_k": self.pool_k, "pool_v": self.pool_v,
                       "near_k": self.near_k, "near_v": self.near_v}
            named = to_named(cache_specs(kv_tree, arch, self.mesh),
                             self.mesh)
            placed = jax.device_put(kv_tree, named)
            self.pool_k, self.pool_v = placed["pool_k"], placed["pool_v"]
            self.near_k, self.near_v = placed["near_k"], placed["near_v"]
        self.tier = tkv.init_tier_state(cfg.n_slots, self.n_pages,
                                        self.pool_pages, cfg.tier.near_pages)
        self.pool = PagePool(self.pool_pages)
        self.prefix = RadixPrefixCache(self.pool, cfg.tier.page) \
            if cfg.share_prefix else None
        # host mirror of per-(slot, page) near residency, re-synced (with
        # the near buffers) once per tick when the mapping moved — drives
        # the independent shadow accounting of far rows touched
        self._promoted_host = np.zeros((cfg.n_slots, self.n_pages), bool)
        self._mapping_dirty = False
        self._shadow_near = None
        self._near_used = 0
        self.pending: dict[int, _PrefillJob] = {}
        self._lane_free = 0.0         # background migration lane drains at
        self._defer_count = 0         # consecutive deferred planning passes
        self.pt_host = -np.ones((cfg.n_slots, self.n_pages), np.int64)
        self.pos = np.zeros(cfg.n_slots, np.int64)
        self.tok = np.zeros(cfg.n_slots, np.int64)
        self.slots: list[_Slot | None] = [None] * cfg.n_slots
        self.free = list(range(cfg.n_slots))
        self.slot_history = {}
        self._near_tokens = np.zeros(cfg.n_slots, np.int64)
        self._static_pinned = np.zeros(cfg.n_slots, bool)
        self._visible_clock: dict[int, float] = {}
        self.report.kv_bytes_dense_equiv = (
            arch.n_layers * cfg.n_slots * cfg.max_len
            * arch.n_kv_heads * hd * jnp.dtype(dtype).itemsize * 2)

        queue = deque(sorted(trace, key=lambda r: (r.arrival, r.rid)))
        chunked = cfg.prefill_chunk_tokens is not None
        tick, clock, steps = 0, 0.0, 0
        t0 = time.perf_counter()
        while queue or self.pending \
                or any(s is not None for s in self.slots):
            for req in queue:                  # sorted by arrival: stop early
                if req.arrival > tick:
                    break
                if req.rid not in self._visible_clock:
                    self._visible_clock[req.rid] = clock
            while queue and queue[0].arrival <= tick and self.free:
                slot = self.free.pop(0)
                if chunked:
                    self._admit_chunked(queue.popleft(), slot)
                else:
                    clock = self._admit(queue.popleft(), slot, clock)
            # a request may want exactly the prefill token (max_new_tokens=1)
            for b in range(cfg.n_slots):
                st = self.slots[b]
                if st is not None and len(st.emitted) >= st.req.max_new_tokens:
                    self._retire(b)
            self._account_kv_bytes()
            active_idx = [b for b, s in enumerate(self.slots) if s is not None]
            # the chunked prefill lane: at most ``prefill_chunk_tokens`` of
            # pending prompt work rides this tick, sharing the decode
            # step's weight stream instead of stalling it
            chunk_toks, completed = 0, []
            if self.pending:
                chunk_toks, completed = self._advance_prefills(
                    self._prefill_budget(len(active_idx)))
            if not active_idx and not chunk_toks and not completed:
                if queue:
                    tick = max(tick + 1, queue[0].arrival)  # idle fast-forward
                else:
                    tick += 1       # unreachable guard: pending implies work
                continue

            ran_decode = False
            if active_idx:
                self._flush_mapping()
                pos_dev = jnp.asarray(self.pos, jnp.int32)
                tokens = {"tokens": jnp.asarray(self.tok[:, None], jnp.int32)}
                meta = self._meta(self.tier, pos_dev)
                kv_cache = {"pool_k": self.pool_k, "pool_v": self.pool_v,
                            "near_k": self.near_k, "near_v": self.near_v,
                            "pos": pos_dev}
                logits, new_cache, aux = self._decode(self.params, kv_cache,
                                                      tokens, meta)
                self.pool_k = new_cache["pool_k"]
                self.pool_v = new_cache["pool_v"]
                if self.fused:
                    # the walk's accounting (device) + an independent host
                    # shadow: both must equal the live non-promoted page rows
                    self.report.far_rows_touched += int(meta["walk_live"].sum())
                    self.report.far_rows_host += self._far_rows_shadow()
                else:
                    # the materializing path gathers the full far view
                    self.report.far_rows_touched += \
                        self.n_pages * cfg.tier.page * cfg.n_slots
                self.report.far_rows_dense += \
                    self.n_pages * cfg.tier.page * cfg.n_slots
                toks = np.asarray(jnp.argmax(logits, axis=-1))[:, 0]

                live = self.pos[active_idx] + 1
                # one fused iteration: decode KV sweep + piggybacked chunk
                # tokens share the tick's weight stream
                clock += cfg.cost.decode_step_cost(
                    self._near_tokens[active_idx], live,
                    kv_shards=self.kv_shards) \
                    + cfg.cost.chunk_prefill_cost(chunk_toks)
                steps += 1
                ran_decode = True
                for b in active_idx:
                    st = self.slots[b]
                    st.emitted.append(int(toks[b]))
                    self.report.token_latencies.append(clock - st.last_emit)
                    st.last_emit = clock
                    self.report.tokens += 1
                    self.pos[b] += 1
                    self.tok[b] = int(toks[b])
                    if len(st.emitted) >= st.req.max_new_tokens:
                        self._retire(b)
            else:
                # prefill-only tick: the chunks stream the weights alone
                clock += cfg.cost.prefill_cost(chunk_toks)
            for slot, job, first in completed:
                self._complete_prefill(slot, job, first, clock)
            if ran_decode and steps % cfg.tier.interval == 0:
                # cost-aware deferral gate (the WMC queue-idle gate
                # generalized to all four policies): while the run queue is
                # hot — arrivals waiting or prompts still chunking — keep
                # migration bandwidth off the critical path, bounded by
                # ``defer_limit`` so sustained load still gets maintenance
                hot = bool(self.pending) \
                    or bool(queue and queue[0].arrival <= tick)
                if hot and self._defer_count < cfg.defer_limit:
                    self._defer_count += 1
                    self.report.migration_deferrals += 1
                else:
                    self._defer_count = 0
                    clock = self._maintain(aux["q0"], clock, not hot)
            tick += 1

        if cfg.overlap_migration:
            # the background lane finishes draining after the last token
            clock = max(clock, self._lane_free)
        self._assert_zero_orphans()
        self.report.steps = steps
        self.report.wall_s = time.perf_counter() - t0
        self.report.modeled_time = clock
        self.report.slot_history = dict(self.slot_history)
        if self.prefix is not None:
            self.report.prefix_lookups = self.prefix.stats.lookups
            self.report.prefix_hits = self.prefix.stats.hits
        return self.report


class DataParallelEngine:
    """Data-parallel serving over the mesh's 'data' axis (docs/design.md
    §2h): R engine replicas, each owning its OWN slot pool, page pool, and
    radix prefix cache, with the offline trace partitioned round-robin by
    arrival order — request i (in (arrival, rid) order) lands on replica
    i % R.  Deterministic, so replica outputs are reproducible and the
    merged report is stable across runs.

    Replicas are *modeled* as parallel: each lane accrues its own byte-cost
    clock (weights stream independently per replica — that is what data
    parallelism buys: R weight streams instead of one), and the merged
    report's ``modeled_time`` is the MAX lane clock.  Host execution is
    sequential through ONE underlying ``ServingEngine`` (its ``run`` fully
    re-initializes all mutable state, so the jitted programs compile once
    and serve every lane) — the model/host split mirrors how the byte-cost
    clock already abstracts device time everywhere else in the engine.

    Decode tokens are batching-invariant (each emitted token is pinned
    bit-identical to single-sequence ``greedy_generate``), so the merged
    ``outputs`` are bit-identical to a single-replica run of the same
    trace regardless of how admissions split across lanes
    (tests/test_mesh_serving.py)."""

    def __init__(self, params, arch: ArchConfig, cfg: ServingConfig,
                 n_replicas: int | None = None):
        if n_replicas is None:
            mesh = cfg.tier.mesh
            n_replicas = mesh.shape.get("data", 1) if mesh is not None else 1
        assert n_replicas >= 1
        self.n_replicas = int(n_replicas)
        self.engine = ServingEngine(params, arch, cfg)

    def run(self, trace: list[Request],
            scenario: str = "trace") -> ServingReport:
        R = self.n_replicas
        order = sorted(trace, key=lambda r: (r.arrival, r.rid))
        lanes = [order[i::R] for i in range(R)]
        reports = [self.engine.run(lane, scenario=scenario)
                   for lane in lanes if lane]
        if not reports:
            return ServingReport(scenario=scenario,
                                 policy=self.engine.cfg.tier.policy,
                                 n_requests=0)
        return merge_lane_reports(reports)


def sequential_baseline(params, arch: ArchConfig, trace: list[Request],
                        cfg: ServingConfig,
                        scenario: str = "trace") -> ServingReport:
    """The no-batching reference: each request served to completion by
    single-sequence ``greedy_generate`` (B=1), one after another, under the
    same modeled cost landscape (no near tier: every live KV token is
    gather-addressed at ``far_cost``)."""
    from repro.launch.serve import greedy_generate, make_decode_step
    report = ServingReport(scenario=scenario, policy="sequential",
                           n_requests=len(trace))
    step_fn = jax.jit(make_decode_step(arch))
    prefill_fn = jax.jit(
        lambda p, b: transformer.prefill(p, b, arch, max_len=cfg.max_len))
    clock = 0.0
    t0 = time.perf_counter()
    for req in sorted(trace, key=lambda r: (r.arrival, r.rid)):
        toks, _ = greedy_generate(
            params, arch, {"tokens": np.asarray(req.prompt)[None]},
            steps=req.max_new_tokens, max_len=cfg.max_len, step_fn=step_fn,
            prefill_fn=prefill_fn)
        report.outputs[req.rid] = np.asarray(toks)[0].tolist()
        S = int(req.prompt.shape[0])
        # TTFT = modeled prefill cost — the same timebase the engine uses
        # (its TTFT is queueing + prefill; the baseline models no queue).
        ttft = cfg.cost.prefill_cost(S)
        clock += ttft
        last = clock
        report.tokens += 1
        report.token_latencies.append(ttft)
        report.ttfts.append(ttft)
        report.prefill_tokens += S
        report.prefill_tokens_full += S
        for i in range(1, req.max_new_tokens):
            clock += cfg.cost.decode_step_cost(np.zeros(1),
                                               np.asarray([S + i]))
            report.token_latencies.append(clock - last)
            last = clock
            report.tokens += 1
        report.steps += req.max_new_tokens - 1
    report.wall_s = time.perf_counter() - t0
    report.modeled_time = clock
    return report
