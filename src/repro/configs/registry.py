"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from repro.configs import (
    deepseek_coder_33b,
    hymba_1p5b,
    kimi_k2_1t_a32b,
    llama4_scout_17b_a16e,
    mamba2_1p3b,
    musicgen_medium,
    qwen2_vl_2b,
    qwen3_1p7b,
    starcoder2_3b,
    yi_9b,
)
from repro.configs.base import ArchConfig, InputShape, SHAPES, shape_applicable

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        kimi_k2_1t_a32b.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
        hymba_1p5b.CONFIG,
        qwen2_vl_2b.CONFIG,
        mamba2_1p3b.CONFIG,
        musicgen_medium.CONFIG,
        deepseek_coder_33b.CONFIG,
        yi_9b.CONFIG,
        qwen3_1p7b.CONFIG,
        starcoder2_3b.CONFIG,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ArchConfig, InputShape, bool, str]]:
    """Every (arch x shape) cell with its applicability verdict."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = shape_applicable(arch, shape)
            out.append((arch, shape, ok, why))
    return out
