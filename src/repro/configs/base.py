"""Architecture and input-shape configuration for the model zoo.

Every assigned architecture is an ``ArchConfig``; every workload shape is an
``InputShape``.  The (arch x shape) grid drives the smoke tests, the multi-pod
dry-run, and the roofline table.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # expert FFN hidden size
    n_shared_experts: int = 0     # always-on shared experts (DeepSeek-style)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    n_heads: int = 0              # SSD heads (0 => derived)
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False           # multimodal rotary (Qwen2-VL)
    sliding_window: int = 0       # 0 => full attention
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    frontend: str = ""            # '' | 'vision' | 'audio' (stubbed)
    n_codebooks: int = 0          # audio: EnCodec codebooks
    tie_embeddings: bool = False
    mlp_gated: bool = True        # SwiGLU (True) vs 2-matrix GELU (False)
    source: str = ""              # provenance note

    # -- derived -------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token contexts? (SSM state or SWA.)"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        n = v * d                       # embedding
        if not self.tie_embeddings:
            n += v * d                  # lm head
        per_layer = 2 * d               # norms
        if self.n_heads:
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            if self.qk_norm:
                per_layer += 2 * hd
        if self.ssm is not None:
            s = self.ssm
            d_inner = s.n_heads * s.head_dim
            per_layer += d * (2 * d_inner + 2 * s.d_state + s.n_heads) \
                + s.d_conv * (d_inner + 2 * s.d_state) + d_inner * d \
                + 2 * s.n_heads + d_inner
        if self.moe is not None:
            m = self.moe
            per_layer += d * m.n_experts  # router
            per_layer += m.n_experts * 3 * d * m.d_expert
            per_layer += m.n_shared_experts * 3 * d * m.d_expert
        elif f:
            per_layer += (3 if self.mlp_gated else 2) * d * f
        return n + L * per_layer + d      # final norm

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        all_expert = self.n_layers * m.n_experts * 3 * self.d_model * m.d_expert
        active_expert = self.n_layers * (m.top_k + m.n_shared_experts) \
            * 3 * self.d_model * m.d_expert
        return full - all_expert + active_expert

    # -- reduced config for CPU smoke tests ----------------------------------

    def reduced(self) -> "ArchConfig":
        kw: dict = dict(
            n_layers=2,
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
        )
        if self.n_heads:
            kw["n_heads"] = 4
            kw["n_kv_heads"] = max(1, min(self.n_kv_heads, 2))
        if self.moe is not None:
            kw["moe"] = MoEConfig(n_experts=4, top_k=min(self.moe.top_k, 2),
                                  d_expert=32,
                                  n_shared_experts=self.moe.n_shared_experts)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, n_heads=4, head_dim=16, chunk=32)
        if self.sliding_window:
            kw["sliding_window"] = 16
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'

    def reduced(self) -> "InputShape":
        return InputShape(self.name, seq_len=min(self.seq_len, 64),
                          global_batch=min(self.global_batch, 2),
                          kind=self.kind)


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason string when skipped."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full attention is quadratic; 500k-token decode skipped"
    return True, ""
