"""repro-lint invariant engine (ISSUE 7 tentpole).

Red-first coverage: every shipped pass must fire on a deliberately broken
mini-step (dense mask tensor, bf16-accumulated read dot, host callback in a
tick, over-budget gather, unpaired pin / denied API / tick host pull) and
stay green on the real serving stack under the committed baseline.  Plus:
walker nesting uniformity (the bug class the old per-test private walkers
had), baseline key stability / staleness reporting, and the CLI exit
contract.
"""

import json
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import walker
from repro.analysis.ownership import lint_ownership
from repro.analysis.passes import (f32_accumulation, no_collectives,
                                   no_dense_far_view, no_host_sync,
                                   vmem_budget)
from repro.analysis.report import AnalysisReport, Violation, violation_key
from repro.analysis.runner import run_analysis
from repro.analysis.targets import AnalysisTarget, ForbiddenShape

B, N_PAGES, C = 5, 7, 3
Hkv, hd = 4, 64


def _target(fn, args, **kw):
    return AnalysisTarget(name="mini", fn=fn, args=args, **kw)


class TestWalker:
    def test_collects_through_nested_scan_pjit(self):
        """One traversal surfaces equations at every nesting depth — the
        uniformity the old per-test walkers re-implemented case by case."""
        def f(x):
            def body(c, xi):
                return c + jnp.sin(xi).sum(), None   # sin inside scan
            out, _ = jax.lax.scan(body, 0.0, x)
            return jax.jit(jnp.cos)(out)             # cos inside pjit
        walked = walker.collect_eqns(jax.make_jaxpr(f)(jnp.ones((3, 4))))
        prims = {(we.prim, we.path) for we in walked}
        assert ("sin", ("scan",)) in prims
        assert ("cos", ("pjit",)) in prims

    def test_intermediate_shapes_spans_depths(self):
        def f(x):
            def body(c, xi):
                return c, jnp.outer(xi, xi)          # (4,4) only in the scan
            _, ys = jax.lax.scan(body, 0.0, x)
            return ys
        shapes = walker.intermediate_shapes(jax.make_jaxpr(f)(jnp.ones((3, 4))))
        assert (4, 4) in shapes

    def test_taint_survives_layout_ops_and_dies_at_arithmetic(self):
        def f(kv, x):
            k = kv.reshape(6, 4).T                  # layout: stays RAW
            s = x @ k                                # dot with RAW operand
            return s @ jnp.ones((6, 2))              # dot on DERIVED only
        walked = walker.collect_eqns(
            jax.make_jaxpr(f)(jnp.ones((4, 6)), jnp.ones((2, 4))),
            kv_invars=[0])
        dots = [we for we in walked if we.prim == "dot_general"]
        assert walker.TAINT_RAW in dots[0].in_taints
        assert walker.TAINT_RAW not in dots[1].in_taints

    def test_taint_flows_through_call_primitives(self):
        """pjit/scan outputs inherit their sub-jaxpr's taint: a padded /
        scanned KV buffer is still raw KV (the suffix-prefill shape)."""
        def f(kv, q):
            kp = jnp.pad(kv, ((0, 2), (0, 0)))       # pjit-wrapped pad

            def body(c, row):
                return c + (q @ row), None           # row is scanned raw KV
            out, _ = jax.lax.scan(body, jnp.zeros((3,)), kp)
            return out
        walked = walker.collect_eqns(
            jax.make_jaxpr(f)(jnp.ones((4, 5)), jnp.ones((3, 5))),
            kv_invars=[0])
        dots = [we for we in walked if we.prim == "dot_general"]
        assert dots and all(walker.TAINT_RAW in d.in_taints for d in dots)

    def test_hlo_ops_present_matches_instructions_not_metadata(self):
        hlo = ("ENTRY %main (p0: f32[8]) -> f32[8] {\n"
               "  %ar = f32[8] all-reduce(%p0), replica_groups={{0,1}}\n"
               "  ROOT %r = f32[8] add(%ar, %ar), metadata={op_name=\""
               "all-gather-ish\"}\n}")
        assert walker.hlo_ops_present(hlo, walker.COLLECTIVE_OPS) == \
            ["all-reduce"]


class TestPlantedViolations:
    """Each pass must flag its deliberately broken mini-step (red) and not
    flag the compliant twin (green)."""

    def test_dense_mask_tensor_fires(self):
        def bad(pt, sop):
            eq = pt[:, :, None] == sop[None, None, :]   # (B, n_pages, C)
            return eq.sum()
        t = _target(bad, (jnp.zeros((B, N_PAGES), jnp.int32),
                          jnp.zeros((C,), jnp.int32)),
                    forbidden_shapes=(ForbiddenShape(
                        (B, N_PAGES, C), "b-npages-c", "planted"),))
        v = no_dense_far_view(t)
        assert len(v) == 1 and v[0].rule == "b-npages-c"

    def test_hoisted_metadata_is_clean(self):
        def ok(pt, lengths):
            return (pt >= 0).sum() + lengths.sum()
        t = _target(ok, (jnp.zeros((B, N_PAGES), jnp.int32),
                         jnp.zeros((B,), jnp.int32)),
                    forbidden_shapes=(ForbiddenShape(
                        (B, N_PAGES, C), "b-npages-c", "planted"),))
        assert no_dense_far_view(t) == []

    def test_bf16_accumulated_read_dot_fires(self):
        def bad(q, pool_k):
            k = pool_k.reshape(-1, Hkv, hd)
            return jnp.einsum("bkd,tkd->bkt", q, k)      # bf16 out, no cast
        t = _target(bad, (jnp.zeros((B, Hkv, hd), jnp.bfloat16),
                          jnp.zeros((37, 8, Hkv, hd), jnp.bfloat16)),
                    kv_args=(1,))
        v = f32_accumulation(t)
        assert len(v) == 1 and "bfloat16" in v[0].detail

    @pytest.mark.parametrize("style", ["preferred", "cast"])
    def test_f32_accumulation_idioms_are_clean(self, style):
        def ok(q, pool_k):
            k = pool_k.reshape(-1, Hkv, hd)
            if style == "preferred":
                return jnp.einsum("bkd,tkd->bkt", q, k,
                                  preferred_element_type=jnp.float32)
            return jnp.einsum("bkd,tkd->bkt", q, k).astype(jnp.float32)
        t = _target(ok, (jnp.zeros((B, Hkv, hd), jnp.bfloat16),
                         jnp.zeros((37, 8, Hkv, hd), jnp.bfloat16)),
                    kv_args=(1,))
        assert f32_accumulation(t) == []

    def test_network_dot_is_exempt(self):
        """A bf16 dot on DERIVED values (attention out @ w_o) is network
        compute, not the read path — the taint lattice must exempt it."""
        def ok(q, pool_k, wo):
            k = pool_k.reshape(-1, Hkv, hd)
            s = jnp.einsum("bkd,tkd->bkt", q, k,
                           preferred_element_type=jnp.float32)
            out = s.astype(jnp.bfloat16).sum(-1)         # (B, Hkv): derived
            return jnp.einsum("bk,km->bm", out, wo)      # bf16 net dot: ok
        t = _target(ok, (jnp.zeros((B, Hkv, hd), jnp.bfloat16),
                         jnp.zeros((37, 8, Hkv, hd), jnp.bfloat16),
                         jnp.zeros((Hkv, 8), jnp.bfloat16)),
                    kv_args=(1,))
        assert f32_accumulation(t) == []

    def test_host_callback_in_tick_fires(self):
        def bad(x):
            return jax.pure_callback(
                lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        t = _target(bad, (jnp.zeros((4,)),))
        v = no_host_sync(t)
        assert len(v) == 1 and "pure_callback" in v[0].detail

    def test_non_tick_target_is_not_checked(self):
        def bad(x):
            return jax.pure_callback(
                lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        t = _target(bad, (jnp.zeros((4,)),), per_tick=False)
        from repro.analysis.passes import PASSES
        p = next(p for p in PASSES if p.name == "no-host-sync")
        assert not p.applies(t)

    def test_over_budget_gather_fires(self):
        """Traced over ShapeDtypeStructs — the 256 MiB far view is priced
        statically, never allocated."""
        pool = jax.ShapeDtypeStruct((100000, 8, Hkv, hd), jnp.bfloat16)
        idx = jax.ShapeDtypeStruct((4, 16384), jnp.int32)

        def bad(pool, i):
            return pool[i].sum()                   # (4,16384,8,Hkv,hd) bf16
        v = vmem_budget(_target(bad, (pool, idx)))
        assert v and all(x.rule == "oversized-intermediate" for x in v)
        assert any("gather" in x.detail for x in v)

    def test_within_budget_gather_is_clean(self):
        pool = jax.ShapeDtypeStruct((370, 8, Hkv, hd), jnp.bfloat16)
        idx = jax.ShapeDtypeStruct((4, 64), jnp.int32)
        assert vmem_budget(_target(lambda p, i: p[i].sum(),
                                   (pool, idx))) == []

    def test_planted_collective_fires(self):
        """no-collectives detection on an HLO module with a real collective
        (synthetic text — single-host CPU lowering cannot emit one)."""
        class Fake(AnalysisTarget):
            def walk(self):
                return []                 # no jaxpr collectives to excuse it
            def hlo_text(self):
                return ("ENTRY %e (p0: f32[4]) -> f32[4] {\n"
                        "  ROOT %ar = f32[4] all-reduce(%p0)\n}")
        t = Fake(name="fake", fn=None, args=(), check_collectives=True)
        v = no_collectives(t)
        assert len(v) == 1 and v[0].rule == "collective-op" \
            and "all-reduce" in v[0].detail

    def _rogue_axis_target(self, **kw):
        """A shard_map collective over a 1-device mesh whose axis name is
        NOT declared anywhere — runs on any host, no forced devices."""
        import numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.asarray(jax.devices()[:1]), ("rogue",))

        def stepish(x):
            return shard_map(lambda xs: jax.lax.psum(xs, "rogue"),
                             mesh=mesh, in_specs=(P(),), out_specs=P(),
                             check_rep=False)(x)
        return _target(stepish, (jnp.ones((4,)),), check_collectives=True,
                       **kw)

    def test_undeclared_axis_collective_fires(self):
        """The mesh-sharding escape hatch must not be a blank check: a
        jaxpr collective over an axis the target has NOT declared is
        flagged even though the op kind (all-reduce) could be declared
        for some other axis."""
        v = no_collectives(self._rogue_axis_target())
        assert any(x.rule == "collective-axis" and "rogue" in x.detail
                   for x in v)

    def test_declared_axis_collective_is_clean(self):
        """The same collective with its axis declared passes both layers:
        the jaxpr check (axis allowed) and the HLO check (the all-reduce
        kind is accounted for by the declared psum)."""
        assert no_collectives(
            self._rogue_axis_target(allowed_axes=("rogue",))) == []

    def test_allowed_axes_merge_from_baseline_through_runner(self):
        """run_analysis merges the baseline file's ``allowed_axes`` into
        targets by name — the declaration is committed config, not a code
        default."""
        t = self._rogue_axis_target()
        rep = run_analysis(mode="dense", targets=[t], with_ownership=False,
                           baseline={},
                           allowed_axes={"mini": ["rogue"]})
        assert rep.ok and t.allowed_axes == ("rogue",)
        t2 = self._rogue_axis_target()
        rep2 = run_analysis(mode="dense", targets=[t2],
                            with_ownership=False, baseline={})
        assert not rep2.ok and \
            rep2.active[0].rule == "collective-axis"


class TestOwnershipLinter:
    def _lint(self, tmp_path, source, name="mod.py"):
        (tmp_path / name).write_text(textwrap.dedent(source))
        return lint_ownership(tmp_path)

    def test_unpaired_alloc_fires(self, tmp_path):
        v = self._lint(tmp_path, """
            def admit(pool):
                pages = pool.allocate(4)      # never released anywhere
                return pages
        """)
        assert any(x.rule == "unpaired-ref" and "allocate" in x.detail
                   for x in v)

    def test_paired_alloc_is_clean(self, tmp_path):
        v = self._lint(tmp_path, """
            def admit(pool):
                return pool.allocate(4)

            def retire(pool, pages):
                pool.release(pages)
        """)
        assert not [x for x in v if x.rule == "unpaired-ref"]

    def test_unpaired_pin_fires(self, tmp_path):
        v = self._lint(tmp_path, """
            from repro.core import tiered_kv as tkv

            def maintain(tier, pages, slots, cfg):
                return tkv.paged_pin_pages(tier, pages, slots, cfg)
        """)
        assert any(x.rule == "unpaired-ref"
                   and "paged_pin_pages" in x.detail for x in v)

    def test_tick_host_pull_fires(self, tmp_path):
        v = self._lint(tmp_path, """
            import numpy as np

            class ServingEngine:
                def run(self, trace):
                    toks = np.asarray(self.logits)    # host pull per token
                    return toks

                def _admit(self, req):
                    return np.asarray(req.prompt)     # boundary: exempt
        """)
        pulls = [x for x in v if x.rule == "tick-host-pull"]
        assert len(pulls) == 1 and "ServingEngine.run" in pulls[0].where

    def test_block_until_ready_fires(self, tmp_path):
        v = self._lint(tmp_path, """
            class ServingEngine:
                def _maintain(self, x):
                    return x.block_until_ready()
        """)
        assert any(x.rule == "tick-host-pull"
                   and "block_until_ready" in x.detail for x in v)

    def test_real_src_has_no_unwaived_findings(self):
        src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
        v = lint_ownership(src)
        assert not [x for x in v if x.rule in ("deny-list", "unpaired-ref",
                                               "syntax-error")]
        # tick host pulls exist but every one is waived by the baseline
        from repro.analysis.report import load_baseline
        from repro.analysis.runner import DEFAULT_BASELINE
        waivers = load_baseline(DEFAULT_BASELINE)
        pulls = [x for x in v if x.rule == "tick-host-pull"]
        assert pulls, "expected the engine's known host-pull sites"
        unwaived = [x.key for x in pulls if x.key not in waivers]
        assert not unwaived, f"new unwaived host pulls: {unwaived}"


class TestBaselineMechanism:
    def test_keys_are_line_independent(self):
        a = Violation("p", "r", "f.py::C.m", "d", source="f.py:10")
        b = Violation("p", "r", "f.py::C.m", "d", source="f.py:999")
        assert a.key == b.key == violation_key("p", "r", "f.py::C.m", "d")

    def test_waiver_and_staleness(self):
        rep = AnalysisReport(violations=[
            Violation("p", "r", "w", "real")])
        rep.apply_baseline({violation_key("p", "r", "w", "real"): "ok",
                            violation_key("p", "r", "w", "gone"): "stale"})
        assert rep.ok and rep.violations[0].waived
        assert rep.unused_baseline == [violation_key("p", "r", "w", "gone")]

    def test_unwaived_violation_fails(self):
        rep = AnalysisReport(violations=[Violation("p", "r", "w", "d")])
        rep.apply_baseline({})
        assert not rep.ok and rep.active


class TestRealStackIsClean:
    def test_analysis_passes_on_current_mode(self, tmp_path):
        """ISSUE 7 acceptance: ``python -m repro.analysis`` exits 0 on main
        under the committed baseline, with no stale waivers — exercised
        in-process through the CLI entry point; CI fans this out over
        dense/gather/fused via REPRO_KERNEL_MODE."""
        from repro.analysis.__main__ import main
        out = tmp_path / "report.json"
        assert main(["--out", str(out)]) == 0
        rep = json.loads(out.read_text())
        assert rep["ok"] and not rep["unused_baseline"]
        assert set(rep["passes_run"]) == {
            "no-dense-far-view", "f32-accumulation", "no-host-sync",
            "vmem-budget", "no-collectives", "pool-ownership"}
        from repro.analysis.targets import kernel_mode
        want = 8 + (1 if kernel_mode() == "fused" and jax.device_count() > 1
                    else 0)   # + the mesh-sharded decode step (mesh-4dev CI)
        assert len(rep["targets_run"]) == want
        assert "chunk_prefill" in rep["targets_run"], \
            "the chunked admission-prefill step must be under analysis"

    def test_planted_target_fails_through_runner(self):
        """End to end: a broken target injected into the runner flips the
        exit contract (the framework is not green by construction)."""
        def bad(pt, sop):
            return (pt[:, :, None] == sop[None, None, :]).sum()
        t = _target(bad, (jnp.zeros((B, N_PAGES), jnp.int32),
                          jnp.zeros((C,), jnp.int32)),
                    forbidden_shapes=(ForbiddenShape(
                        (B, N_PAGES, C), "b-npages-c", "planted"),))
        rep = run_analysis(mode="dense", targets=[t], with_ownership=False,
                           baseline={})
        assert not rep.ok
        assert rep.active[0].pass_name == "no-dense-far-view"
