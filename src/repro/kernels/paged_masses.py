"""Pallas kernel: pool-native per-page attention-mass reduction.

The scoring pass (the paper's interval-sampled activation counts) used to
materialize every slot's full far view — the exact gather the fused decode
kernel (`kernels.paged_attention`) eliminated from the read path — just to
softmax it and sum per page.  With the pool as the single source of truth
(ISSUE 5) scoring walks the page table the same way the read does:

  grid (B, Hkv); per step the kernel walks the slot's SCORE walk list
  (ALL mapped, live pages — near-resident pages included, so retention
  scores stay fresh; contrast the read walk, which skips promoted pages),
  issuing ONE async pool->VMEM copy per page and accumulating an
  online-softmax numerator PER WALK ENTRY (a (g, W) accumulator rescaled
  by the running max), so the per-page probability masses come out of one
  pass with no (B, T) score tensor and no far-view materialization.

Only ``pool_k`` is touched — masses need scores, not values — so the
scoring pass moves half the bytes of even a hypothetical fused read over
the same pages.

Returns (B, W) f32: per walk entry, the attention mass summed over ALL
query heads (callers divide by H and scatter entries back to slot-page
positions via the walk's ``score_j``).  ``paged_masses_ref`` is the
pure-jnp oracle the kernel is validated against.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_masses_kernel(h_ref, pid_ref, live_ref, len_ref, q_ref,
                         pool_k_ref, o_ref, kbuf, sem_k, *,
                         page: int, n_walk: int, scale: float):
    h = h_ref[0]                       # this grid step's KV head (SMEM iota)
    q = q_ref[0, 0].astype(jnp.float32) * scale               # (g, hd)
    g, hd = q.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)

    def body(i, carry):
        psum, m, l = carry
        pid = pid_ref[0, i]
        cp = pltpu.make_async_copy(pool_k_ref.at[pid, :, h], kbuf, sem_k)
        cp.start()
        cp.wait()
        kp = kbuf[...].astype(jnp.float32)                    # (page, hd)
        s = jax.lax.dot_general(q, kp, (((1,), (1,)), ((), ())))
        alive = row < live_ref[0, i]
        s = jnp.where(alive, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(alive, jnp.exp(s - m_new), 0.0)
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        # rescale every prior entry's numerator, then deposit this one's
        psum_new = jax.lax.dynamic_update_slice(
            psum * alpha, p.sum(axis=1, keepdims=True), (0, i))
        return psum_new, m_new, l_new

    psum = jnp.zeros((g, n_walk), jnp.float32)
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    psum, m, l = jax.lax.fori_loop(0, len_ref[0], body, (psum, m, l))
    o_ref[0, 0] = (psum / jnp.maximum(l, 1e-30)).sum(axis=0)


def paged_masses(q: jax.Array, pool_k: jax.Array, score_pid: jax.Array,
                 score_live: jax.Array, score_len: jax.Array,
                 interpret: bool = False, mesh=None) -> jax.Array:
    """Pool-native per-page attention masses.

    q: (B, H, hd) scoring queries (GQA: H a multiple of Hkv).
    pool_k: (P, page, Hkv, hd) shared far pool (stays in HBM/ANY).
    score_pid/score_live: (B, W) int32 — per slot, the pool ids of its
      mapped LIVE pages (front-packed, near-resident included) and each
      page's live row count; entries past ``score_len[b]`` unused.
    score_len: (B,) int32.

    Returns (B, W) f32: per walk entry, softmax attention mass summed over
    all H heads (entries past score_len are exactly zero).

    With a ``mesh`` whose 'model' axis divides Hkv the pool is
    KV-HEAD-SHARDED: the kernel runs per shard over its head slice (each
    head's softmax is independent) and the cross-head sum finishes with a
    ``psum`` over 'model'.  Unlike the read path this output IS a cross-
    head reduction, so its last bit may differ from the single-device sum
    order — masses drive page *placement* only, and emitted tokens are
    placement-invariant (the policy-parity pin)."""
    from repro.sharding.specs import kv_shard_count
    if mesh is not None and kv_shard_count(mesh, pool_k.shape[-2]) > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P_
        B, H, hd = q.shape
        Hkv = pool_k.shape[-2]
        g = H // Hkv

        def local_masses(q4, pk, s_pid, s_live, s_len):
            Hl = pk.shape[-2]
            out = paged_masses(q4.reshape(B, Hl * g, hd), pk, s_pid, s_live,
                               s_len, interpret=interpret)
            return jax.lax.psum(out, "model")

        sharded = shard_map(
            local_masses, mesh=mesh,
            in_specs=(P_(None, "model"), P_(None, None, "model"),
                      P_(), P_(), P_()),
            out_specs=P_(),
            check_rep=False)
        return sharded(q.reshape(B, Hkv, g, hd), pool_k, score_pid,
                       score_live, score_len)
    B, H, hd = q.shape
    P, page, Hkv, _ = pool_k.shape
    g = H // Hkv
    W = score_pid.shape[1]
    q4 = q.reshape(B, Hkv, g, hd)
    heads = jnp.arange(Hkv, dtype=jnp.int32)
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)

    kernel = functools.partial(_paged_masses_kernel, page=page, n_walk=W,
                               scale=hd ** -0.5)
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv),
        in_specs=[
            smem((1,), lambda b, h: (h,)),
            smem((1, W), lambda b, h: (b, 0)),
            smem((1, W), lambda b, h: (b, 0)),
            smem((1,), lambda b, h: (b,)),
            pl.BlockSpec((1, 1, g, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, W), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, W), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((page, hd), pool_k.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(heads, i32(score_pid), i32(score_live), i32(score_len), q4, pool_k)
    return out.sum(axis=1)


def paged_masses_ref(q: jax.Array, pool_k: jax.Array, score_pid: jax.Array,
                     score_live: jax.Array,
                     score_len: jax.Array) -> jax.Array:
    """Materializing oracle: gather the walked pages, softmax, page-sum."""
    B, H, hd = q.shape
    P, page, Hkv, _ = pool_k.shape
    g = H // Hkv
    W = score_pid.shape[1]
    k = pool_k[score_pid]                         # (B, W, page, Hkv, hd)
    qh = q.reshape(B, Hkv, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgd,bwpkd->bkgwp", qh, k.astype(jnp.float32))
    walk_ok = (jnp.arange(W)[None, :] < score_len[:, None])   # (B, W)
    alive = walk_ok[:, None, None, :, None] & \
        (jnp.arange(page)[None, None, None, None, :]
         < score_live[:, None, None, :, None])
    s = jnp.where(alive, s, NEG_INF)
    flat = s.reshape(B, Hkv, g, W * page)
    p = jax.nn.softmax(flat, axis=-1).reshape(s.shape)
    p = jnp.where(alive, p, 0.0)
    return p.sum(axis=(1, 2, 4))                  # (B, W)
