"""MusicGen-medium: decoder-only LM over EnCodec audio tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144
vocab=2048 (per codebook), 4 codebooks with a delay pattern.  The EnCodec
frontend is a stub: ``input_specs()`` provides precomputed frame embeddings.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    n_codebooks=4,
    frontend="audio",
    mlp_gated=False,           # MusicGen uses a 2-matrix GELU FFN
    source="arXiv:2306.05284; hf",
)
