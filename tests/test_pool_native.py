"""Pool-native serving: the paged pool is the single source of truth
(ISSUE 5 tentpole).

Pins the ownership inversion end to end:

  (1) the dense per-slot KV master is GONE — grep-provable: no
      ``refresh_pool_from_slots`` anywhere under ``src/`` (there is nothing
      left to refresh a pool *from*);
  (2) the materializing (non-fused) paged decode step over pool bytes is
      BIT-identical to the retired PR-4 dense-master reduction
      (``decode_step``) — same values, same ``decode_attention`` kernel,
      just gathered through the page table;
  (3) ``kv_bytes_live`` (peak referenced pool pages + near copies) is
      <= 0.6x the dense-equivalent master's bytes on the
      shared_system_prompt and long_context_summarize traces — the
      acceptance the PR's memory claim rests on;
  (4) the shutdown refcount sweep proves zero orphaned pages through
      retire + prefix-LRU-eviction churn, and actually detects planted
      leaks (the sweep must not be a tautology);
  (5) the pool-native page-mass reduction kernel (`kernels.paged_masses`)
      matches its materializing oracle, and the fused scoring route of
      ``paged_page_masses`` matches the XLA scoring route.
"""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core import tiered_kv as tkv
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer
from repro.serve import ServingConfig, ServingEngine
from repro.serve.trace import SCENARIOS


def _arch_params(seed=0):
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(seed), arch)
    return arch, params


class TestDenseMasterIsGone:
    def test_refresh_pool_from_slots_absent_from_src(self):
        """Acceptance: the slots->pool refresh pass cannot exist when the
        pool is the only store.  The old text grep is now the ownership
        linter's deny-list (``repro.analysis.ownership``): the AST pass
        must report zero deny-list hits over ``src/``."""
        from repro.analysis.ownership import lint_ownership
        src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
        hits = [v for v in lint_ownership(src) if v.rule == "deny-list"]
        assert not hits, f"dense-master refresh still referenced: " \
                         f"{[(v.where, v.detail) for v in hits]}"

    def test_deny_list_covers_refresh_pool_from_slots(self):
        """The deny-list actually bans the API this pin retired — and the
        linter actually fires on a planted resurrection (the pin is not a
        tautology)."""
        import textwrap
        from repro.analysis.ownership import DENY_APIS, lint_ownership
        assert "refresh_pool_from_slots" in DENY_APIS
        import tempfile
        with tempfile.TemporaryDirectory() as td:
            planted = pathlib.Path(td) / "resurrected.py"
            planted.write_text(textwrap.dedent("""
                def refresh_pool_from_slots(pool, slots):
                    return pool
            """))
            hits = [v for v in lint_ownership(td) if v.rule == "deny-list"]
        assert len(hits) == 1 and "refresh_pool_from_slots" in hits[0].detail

    def test_paged_decode_cache_has_no_dense_kv_leaves(self):
        """The engine's decode cache pytree carries pool/near leaves only."""
        arch, params = _arch_params()
        tier = TieredKVConfig(page=16, near_pages=2, interval=4)
        eng = ServingEngine(params, arch,
                            ServingConfig(n_slots=2, max_len=32,
                                          prefill_bucket=16, tier=tier))
        trace = SCENARIOS["steady_zipfian"](arch.vocab, n_requests=2,
                                            prompt_len=10, max_new_tokens=4,
                                            gap=1)
        eng.run(trace, "t")
        assert not hasattr(eng, "cache"), "dense per-slot cache resurrected"
        for leaf in ("pool_k", "pool_v", "near_k", "near_v"):
            assert hasattr(eng, leaf)


class TestMaterializingPathBitIdentical:
    def test_pool_materialized_decode_equals_dense_master_decode(self):
        """(2): write the SAME prefill rows into a dense per-slot cache and
        into pool pages; one decode step through each path must produce
        bit-identical logits — the pool changed where bytes live, not one
        bit of the math."""
        arch, params = _arch_params(seed=3)
        B, S, page, n_pages = 3, 24, 16, 4
        max_len = page * n_pages
        P = B * n_pages + 2
        C = 2
        tier = TieredKVConfig(page=page, near_pages=C)
        toks = jax.random.randint(jax.random.key(5), (B, S), 0, arch.vocab)
        _, cache = transformer.prefill(params, {"tokens": toks}, arch,
                                       max_len=max_len)
        pos = jnp.full((B,), S, jnp.int32)
        cache["pos"] = pos
        step_tok = {"tokens": jnp.full((B, 1), 7, jnp.int32)}
        la, ca = transformer.decode_step(params, cache, step_tok, arch)

        # scatter the same rows into per-layer pool pages
        L = arch.n_layers
        hd = arch.resolved_head_dim
        pool_k = jnp.zeros((L, P, page, arch.n_kv_heads, hd),
                           cache["k"].dtype)
        pool_v = jnp.zeros_like(pool_k)
        pt = np.arange(B * n_pages, dtype=np.int32).reshape(B, n_pages)
        for b in range(B):
            rk = cache["k"][:, b].reshape(L, n_pages, page, arch.n_kv_heads,
                                          hd)
            rv = cache["v"][:, b].reshape(L, n_pages, page, arch.n_kv_heads,
                                          hd)
            pool_k = pool_k.at[:, pt[b]].set(rk)
            pool_v = pool_v.at[:, pt[b]].set(rv)
        state = tkv.init_tier_state(B, n_pages, P, C)
        state["page_table"] = jnp.asarray(pt)
        meta = tkv.paged_step_metadata(state, pos + 1, tier, append_pos=pos,
                                       pool_pages=P)
        pcache = {"pool_k": pool_k, "pool_v": pool_v,
                  "near_k": jnp.zeros((L, C * page, arch.n_kv_heads, hd),
                                      pool_k.dtype),
                  "near_v": jnp.zeros((L, C * page, arch.n_kv_heads, hd),
                                      pool_k.dtype),
                  "pos": pos}
        lb, cb = transformer.paged_decode_step(params, pcache, step_tok,
                                               arch, meta, fused=False)
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))
        # and the appended token landed in the pool exactly where the dense
        # path put it in its rows
        for b in range(B):
            pid, off = S // page, S % page
            np.testing.assert_array_equal(
                np.asarray(cb["pool_k"][:, pt[b, pid], off], np.float32),
                np.asarray(ca["k"][:, b, S], np.float32))


class TestKVBytesAcceptance:
    def _ratio(self, scenario_cfg, trace_kw, eng_kw):
        arch, params = _arch_params(seed=1)
        trace = SCENARIOS[scenario_cfg](arch.vocab, **trace_kw)
        tier = TieredKVConfig(page=16, near_pages=2, interval=4,
                              policy="BBC")
        cfg = ServingConfig(prefill_bucket=16, tier=tier, share_prefix=True,
                            **eng_kw)
        rep = ServingEngine(params, arch, cfg).run(trace, scenario_cfg)
        assert rep.kv_bytes_live > 0 and rep.kv_bytes_dense_equiv > 0
        return rep

    def test_shared_system_prompt_live_kv_below_0p6_dense(self):
        rep = self._ratio("shared_system_prompt",
                          dict(n_requests=8, sys_len=64, user_len=16,
                               max_new_tokens=12, gap=2),
                          dict(n_slots=6, max_len=128))
        assert rep.kv_live_ratio <= 0.6, \
            f"live KV {rep.kv_live_ratio:.3f}x dense-equivalent (> 0.6)"

    def test_long_context_summarize_live_kv_below_0p6_dense(self):
        rep = self._ratio("long_context_summarize",
                          dict(n_requests=4, doc_len=96, question_len=16,
                               max_new_tokens=8, gap=3),
                          dict(n_slots=3, max_len=128))
        assert rep.kv_live_ratio <= 0.6, \
            f"live KV {rep.kv_live_ratio:.3f}x dense-equivalent (> 0.6)"


class TestZeroOrphanedPages:
    def _run_engine(self, pool_pages=None):
        arch, params = _arch_params(seed=2)
        tier = TieredKVConfig(page=16, near_pages=2, interval=3,
                              policy="BBC")
        cfg = ServingConfig(n_slots=3, max_len=96, prefill_bucket=16,
                            tier=tier, share_prefix=True,
                            pool_pages=pool_pages)
        trace = SCENARIOS["multi_turn_chat"](arch.vocab, n_sessions=4,
                                             turns=4, base_len=24,
                                             turn_len=16, max_new_tokens=6,
                                             think_gap=8)
        eng = ServingEngine(params, arch, cfg)
        rep = eng.run(trace, "multi_turn_chat")
        return eng, rep

    def test_release_plus_lru_eviction_leaves_zero_orphans(self):
        """ISSUE 5 satellite: drive retire + prefix-LRU-eviction churn with
        a minimum-size pool (eviction pressure on every later admit); the
        engine's shutdown sweep runs inside ``run`` — reaching this line
        proves zero orphans — and the pool partition is re-checked here."""
        eng, _ = self._run_engine(pool_pages=3 * 6)   # minimum legal pool
        assert eng.prefix.stats.evictions > 0, \
            "test must exercise the LRU eviction path"
        assert (eng.pool.refcount == 0).all()
        free = set(int(p) for p in eng.pool._free)
        cached = set(np.flatnonzero(eng.pool.cached).tolist())
        assert free | cached == set(range(eng.pool_pages))
        assert not (free & cached)
        assert cached == eng.prefix.cached_pages()

    def test_sweep_detects_planted_refcount_leak(self):
        """The sweep must not be a tautology: a planted leaked reference
        (and a retention flag the trie does not own) must both raise."""
        eng, _ = self._run_engine()
        eng.pool.refcount[0] += 1
        with pytest.raises(RuntimeError, match="orphaned"):
            eng._assert_zero_orphans()
        eng.pool.refcount[0] -= 1
        eng._assert_zero_orphans()                    # clean again
        victim = next(p for p in range(eng.pool_pages)
                      if not eng.pool.cached[p])
        eng.pool.cached[victim] = True
        with pytest.raises(RuntimeError, match="diverge|partition"):
            eng._assert_zero_orphans()


class TestPagedMassesKernel:
    def _random_state(self, seed, B=3, n_pages=5, P=18, page=8, HKV=2, HD=8,
                      C=3):
        rng = np.random.default_rng(seed)
        cfg = TieredKVConfig(page=page, near_pages=C, interval=2,
                             fused_kernel=True)
        cache = tkv.init_paged_cache(cfg, B, n_pages, P, HKV, HD,
                                     dtype=jnp.float32)
        cache["pool_k"] = jnp.asarray(
            rng.normal(size=cache["pool_k"].shape), jnp.float32)
        cache["pool_v"] = jnp.asarray(
            rng.normal(size=cache["pool_v"].shape), jnp.float32)
        # rows map a prefix of pages (the engine's partial-mapping shape),
        # drawn from distinct pool pages
        pt = -np.ones((B, n_pages), np.int32)
        perm = rng.permutation(P)
        k = 0
        n_mapped = rng.integers(1, n_pages + 1, size=B)
        for b in range(B):
            for j in range(int(n_mapped[b])):
                pt[b, j] = perm[k]
                k += 1
        cache["page_table"] = jnp.asarray(pt)
        pos = np.minimum(n_mapped * page - rng.integers(0, page, size=B),
                         n_mapped * page)
        q = jnp.asarray(rng.normal(size=(B, HKV * 2, HD)), jnp.float32)
        return cfg, cache, jnp.asarray(pos, jnp.int32), q

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_kernel_matches_materializing_oracle(self, seed):
        from repro.kernels.paged_masses import paged_masses, paged_masses_ref
        cfg, cache, pos, q = self._random_state(seed)
        walk = tkv.paged_score_walk(cache, pos, cfg)
        got = paged_masses(q, cache["pool_k"], walk["score_pid"],
                           walk["score_live"], walk["score_len"],
                           interpret=True)
        want = paged_masses_ref(q, cache["pool_k"], walk["score_pid"],
                                walk["score_live"], walk["score_len"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        # entries past each slot's walk length are exactly zero
        g = np.asarray(got)
        for b in range(g.shape[0]):
            assert (g[b, int(walk["score_len"][b]):] == 0).all()

    @pytest.mark.parametrize("seed", [3, 4])
    def test_fused_scoring_route_matches_xla_route(self, seed):
        """``paged_page_masses`` through the pool-native kernel equals the
        materializing XLA scorer — including near-resident pages (the
        score walk must NOT skip promoted pages)."""
        cfg, cache, pos, q = self._random_state(seed)
        for _ in range(4):      # EMA buildup past the promotion threshold
            cache = tkv.paged_plan_and_migrate(cache, q, pos, cfg)
        assert int((np.asarray(cache["page_of_slot"]) >= 0).sum()) > 0, \
            "state must include a promoted page"
        fused = tkv.paged_page_masses(q, cache, pos, cfg)
        import dataclasses
        dense_cfg = dataclasses.replace(cfg, fused_kernel=False)
        dense = tkv.paged_page_masses(q, cache, pos, dense_cfg)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(dense),
                                   rtol=1e-5, atol=1e-6)
