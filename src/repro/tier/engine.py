"""Per-access NumPy tier engine, batched across tier groups (docs/tier.md).

One `TierEngine` instance holds the near-segment state for *every* group of a
device — the DRAM simulator's full bank x subarray grid — as a struct of
dense arrays instead of per-subarray dict objects:

    slot_of_row : (G, N) int32   near slot per far row, -1 if far-resident
    row_of_slot : (G, C) int32   far row per near slot, -1 if empty
    score       : (G, N) f64     decayed activation counts (BBC benefit)
    last_use    : (G, N) f64     last access time (SC/WMC LRU)
    dirty       : (G, N) bool    near rows needing a write-back IST on evict
    slot_seq    : (G, C) i64     promotion order (eviction tie-break)

Per-access operations are O(1) array writes plus an O(C) victim scan; score
decay is a single vector multiply per group every ``decay_period`` accesses.
This replaces the per-request Python dict layer (`CacheState` + per-key
loops) that was the simulator's policy-side bottleneck, and makes the state
layout identical to the jittable interval engine (`repro.tier.jax_engine`).

The decision arithmetic itself lives in `repro.tier.rules` and is shared with
the JAX engine; `tests/test_tier_parity.py` replays identical access streams
through this engine and the object oracle (`repro.tier.reference`) for all
four policies and asserts decision-for-decision parity.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.tier import rules
from repro.tier.costs import TierCosts


@dataclass
class Decision:
    """Outcome of one per-access policy decision."""

    promote: bool = False
    victim_row: int = -1        # far row to evict; -1 => an empty slot is used
    victim_dirty: bool = False  # eviction needs a write-back IST
    slot: int = -1              # near slot the candidate lands in


class TierEngine:
    """All four paper policies over array state, G independent groups."""

    def __init__(self, policy: str, costs: TierCosts, groups: int, rows: int,
                 capacity: int, decay_period: int = 16):
        policy = policy.upper()
        if policy not in rules.POLICY_NAMES:
            raise ValueError(f"unknown policy {policy!r}")
        if capacity < 1:
            raise ValueError("near-segment capacity must be >= 1 "
                             "(use an untiered device for capacity 0)")
        self.policy = policy
        self.costs = costs
        self.G, self.N, self.C = groups, rows, capacity
        self.decay_period = decay_period
        self.slot_of_row = np.full((groups, rows), -1, np.int32)
        self.row_of_slot = np.full((groups, capacity), -1, np.int32)
        self.score = np.zeros((groups, rows))
        self.last_use = np.zeros((groups, rows))
        self.dirty = np.zeros((groups, rows), bool)
        self.slot_seq = np.zeros((groups, capacity), np.int64)
        # Scalar per-group counters stay Python ints: they are touched on
        # every access and list indexing beats NumPy scalar round-trips.
        self.occupancy = [0] * groups
        self._since_decay = [0] * groups
        self._seq = 0

    # -- queries -------------------------------------------------------------

    def hit(self, g: int, row: int) -> bool:
        return self.slot_of_row[g, row] >= 0

    def slot(self, g: int, row: int) -> int:
        return self.slot_of_row[g, row].item()

    # -- bookkeeping ---------------------------------------------------------

    def on_access(self, g: int, row: int, now: float, is_write: bool,
                  in_near: bool, activated: bool = True) -> None:
        """Record one access; decays the group's scores every
        ``decay_period`` accesses (hits and misses both count)."""
        self.last_use[g, row] = now
        # The near segment saves latency/energy per ACTIVATION, not per
        # column access: row-buffer hits are free either way.
        if activated:
            self.score[g, row] += 1.0
        if in_near and is_write:
            self.dirty[g, row] = True
        n = self._since_decay[g] + 1
        self._since_decay[g] = n
        if n >= self.decay_period:
            self._since_decay[g] = 0
            s = self.score[g]
            np.multiply(s, self.costs.decay, out=s)
            s[s < rules.SCORE_FLOOR] = 0.0

    # -- decision ------------------------------------------------------------

    def decide(self, g: int, row: int, now: float,
               bank_idle: bool) -> Decision:
        """Should the far row just accessed be promoted, and at whose cost?"""
        policy = self.policy
        if policy == "STATIC":
            return Decision()
        score = self.score[g, row]
        if not bool(rules.eligible(policy, score, True, self.costs, np)):
            return Decision()
        victim_row, victim_slot, victim_empty = self._select_victim(g)
        victim_score = 0.0 if victim_empty else self.score[g, victim_row]
        victim_dirty = (not victim_empty) and bool(self.dirty[g, victim_row])
        ok = rules.accept(policy, score, victim_score, victim_dirty,
                          victim_empty, bank_idle, self.costs, np)
        if not bool(ok):
            return Decision()
        return Decision(promote=True,
                        victim_row=-1 if victim_empty else victim_row,
                        victim_dirty=victim_dirty, slot=victim_slot)

    def _select_victim(self, g: int) -> tuple[int, int, bool]:
        """(victim_row, slot, empty): first empty slot if any, else the
        minimum of the policy's eviction key, ties to the oldest promotion
        (matching the reference oracle's dict-insertion order)."""
        if self.occupancy[g] < self.C:
            return -1, int(np.argmax(self.row_of_slot[g] < 0)), True
        resident = self.row_of_slot[g]
        key = rules.victim_order_key(self.policy, self.score[g],
                                     self.last_use[g])[resident]
        tied = np.nonzero(key == key.min())[0]
        slot = int(tied[np.argmin(self.slot_seq[g, tied])])
        return int(resident[slot]), slot, False

    # -- state updates -------------------------------------------------------

    def apply(self, g: int, row: int, d: Decision) -> None:
        """Commit a promotion decision (the IST itself is the caller's)."""
        if d.victim_row >= 0:
            self.slot_of_row[g, d.victim_row] = -1
            self.dirty[g, d.victim_row] = False
        else:
            self.occupancy[g] += 1
        self.row_of_slot[g, d.slot] = row
        self.slot_of_row[g, row] = d.slot
        self._seq += 1
        self.slot_seq[g, d.slot] = self._seq

    def preload(self, counts: np.ndarray,
                first_seen: np.ndarray | None = None) -> None:
        """STATIC profile placement: per group, fill slots with the hottest
        rows (count ties broken by first occurrence — the profiling pass's
        observation order, like the reference oracle's dict ordering).

        counts     : (G, N) profiled access counts.
        first_seen : (G, N) index of each row's first access (optional).
        """
        for g in range(self.G):
            c = counts[g]
            idx = np.nonzero(c > 0)[0]
            tie = first_seen[g, idx] if first_seen is not None else idx
            order = idx[np.lexsort((tie, -c[idx]))]
            take = order[: self.C]
            for slot, row in enumerate(take):
                self.row_of_slot[g, slot] = row
                self.slot_of_row[g, row] = slot
                self.slot_seq[g, slot] = slot
            self.occupancy[g] = len(take)
