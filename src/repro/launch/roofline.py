"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh) cell, from the compiled per-device SPMD module:

  compute term    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16, v5e)
  memory term     = HLO_bytes / HBM_bw                (819 GB/s)
  collective term = wire_bytes_per_device / link_bw   (50 GB/s/link ICI)

(The per-device HLO already divides by the chip count, so the brief's
"/ chips" is implicit.)  MODEL_FLOPS uses the 6*N_active*D convention for
training and 2*N_active*D for inference steps; the ratio MODEL/HLO flags
remat/redundancy waste.  The roofline fraction reported in §Perf is

  fraction = ideal_time / bound_time
  ideal_time = MODEL_FLOPS_per_device / peak
  bound_time = max(compute, memory, collective)

Usage: python -m repro.launch.roofline [--dir artifacts/dryrun] [--mesh single]
"""

from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from pathlib import Path

from repro.configs.registry import ARCHS, SHAPES

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_dev: float
    hlo_flops_dev: float
    temp_gb: float

    @property
    def bound(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_dev / self.hlo_flops_dev \
            if self.hlo_flops_dev else 0.0

    @property
    def roofline_fraction(self) -> float:
        ideal = self.model_flops_dev / PEAK_FLOPS
        return ideal / self.bound_s if self.bound_s else 0.0


def model_flops_per_device(arch_name: str, shape_name: str,
                           n_devices: int) -> float:
    arch = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    n = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / n_devices
    tokens = shape.global_batch           # one new token per sequence
    return 2.0 * n * tokens / n_devices


def load_cells(art_dir: Path, mesh: str = "single") -> list[CellRoofline]:
    cells = []
    for path in sorted(art_dir.glob("*.json")):
        art = json.loads(path.read_text())
        if art.get("status") != "ok" or art.get("mesh") != mesh:
            continue
        if "__" in path.stem and len(path.stem.split("__")) > 3:
            continue  # tagged experiment artifacts are not baseline cells
        h = art["hlo"]
        cells.append(CellRoofline(
            arch=art["arch"], shape=art["shape"], mesh=art["mesh"],
            compute_s=h["flops"] / PEAK_FLOPS,
            memory_s=h["bytes"] / HBM_BW,
            collective_s=h["collective_wire_bytes"] / LINK_BW,
            model_flops_dev=model_flops_per_device(
                art["arch"], art["shape"], art["n_devices"]),
            hlo_flops_dev=h["flops"],
            temp_gb=art["memory"]["temp_bytes"] / 1e9,
        ))
    return cells


def render_table(cells: list[CellRoofline]) -> str:
    header = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) "
              "| bound | 6ND/HLO | roofline frac | temp GB |\n"
              "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
        rows.append(
            f"| {c.arch} | {c.shape} | {c.compute_s*1e3:.2f} "
            f"| {c.memory_s*1e3:.2f} | {c.collective_s*1e3:.2f} "
            f"| **{c.bound}** | {c.useful_ratio:.2f} "
            f"| {c.roofline_fraction:.3f} | {c.temp_gb:.1f} |")
    return header + "\n".join(rows) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args(argv)
    cells = load_cells(Path(args.dir), args.mesh)
    print(render_table(cells))
    Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.json_out).write_text(json.dumps(
        [c.__dict__ | {"bound": c.bound, "useful_ratio": c.useful_ratio,
                       "roofline_fraction": c.roofline_fraction}
         for c in cells], indent=1))
    worst = sorted(cells, key=lambda c: c.roofline_fraction)[:5]
    print("\nworst roofline fractions:")
    for c in worst:
        print(f"  {c.arch} {c.shape}: {c.roofline_fraction:.3f} ({c.bound})")
    coll = sorted(cells, key=lambda c: -c.collective_s)[:5]
    print("most collective-bound:")
    for c in coll:
        print(f"  {c.arch} {c.shape}: collective {c.collective_s*1e3:.2f} ms")


if __name__ == "__main__":
    main()
