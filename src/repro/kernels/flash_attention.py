"""Pallas TPU flash-attention forward kernel (causal, GQA, optional window).

Grid: (batch, q_heads, S / block_q).  Per step, one (block_q, hd) query tile
and this head's full (T, hd) K/V panels are resident in VMEM; the kernel
streams K/V in (block_kv, hd) sub-tiles with the online-softmax recurrence.
MXU alignment: block_q and block_kv are multiples of 128 when the shape
allows, hd is the lane dimension.

VMEM budget per step (bf16): (2*T + block_q)*hd*2B + O(block_q*block_kv*4B)
— e.g. T=4096, hd=128, block_q=block_kv=128: ~2.2 MB, comfortably inside the
~16 MB/core VMEM of TPU v5e.  For longer sequences the model uses the jnp
scan formulation (`repro.models.layers.flash_attention`); this kernel is the
hot-path for training blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_kv: int,
                  seq_k: int, causal: bool, window: int, scale: float):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # (bq, hd)
    bq, hd = q.shape

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_kv = seq_k // block_kv

    def body(i, carry):
        acc, m, l = carry
        k = k_ref[0, pl.ds(i * block_kv, block_kv), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * block_kv, block_kv), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
        k_pos = i * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        mask = jnp.ones((bq, block_kv), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return acc_new, m_new, l_new

    acc = jnp.zeros((bq, hd), jnp.float32)
    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_kv, body, (acc, m, l))
    out = acc / jnp.maximum(l, 1e-30)
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_kv: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,S,Hkv,hd) (self-attention, T == S)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_kv=block_kv, seq_k=S,
        causal=causal, window=window, scale=hd ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=(B, H, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h // g, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
