"""Unified decoder for every assigned architecture family.

One parameter layout, one scan-over-layers apply, four block flavours:

  dense / vlm / audio : attn + SwiGLU
  moe                 : attn + MoE (EP-shardable dispatch)
  ssm                 : SSD mixer only (attention-free)
  hybrid (Hymba)      : parallel attn + SSD heads, merged, then SwiGLU

Layers are stacked along a leading L axis and applied with ``jax.lax.scan``
(small HLO, O(1) compile cost in depth) with configurable rematerialization.
Decode uses an explicit cache pytree; sliding-window attention uses a ring
buffer of size `window` so the 500k-token shapes keep O(window) KV state.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.sharding import ctx
from repro.models.layers import (
    apply_mrope, apply_rope, decode_attention, flash_attention, gelu_mlp,
    paged_decode_attention, rms_norm, swiglu,
)

Params = dict
Cache = dict


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_attn(key, arch: ArchConfig, dtype):
    hd = arch.resolved_head_dim
    D, H, Hkv = arch.d_model, arch.n_heads, arch.n_kv_heads
    ks = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (D, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (D, Hkv, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (D, Hkv, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, D)) * (H * hd) ** -0.5
               ).astype(dtype),
    }
    if arch.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _init_mlp(key, arch: ArchConfig, dtype):
    D, F = arch.d_model, arch.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": (jax.random.normal(ks[1], (D, F)) * D ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (F, D)) * F ** -0.5).astype(dtype),
    }
    if arch.mlp_gated:
        p["w_gate"] = (jax.random.normal(ks[0], (D, F)) * D ** -0.5
                       ).astype(dtype)
    return p


def _init_layer(key, arch: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    D = arch.d_model
    p: dict = {}
    if arch.family == "ssm":
        p["ssm_norm"] = jnp.ones((D,), dtype)
        p["ssm"] = ssm_lib.init_ssm_params(ks[0], D, arch.ssm, dtype)
        return p
    p["attn_norm"] = jnp.ones((D,), dtype)
    p["attn"] = _init_attn(ks[0], arch, dtype)
    if arch.family == "hybrid":
        p["ssm"] = ssm_lib.init_ssm_params(ks[1], D, arch.ssm, dtype)
        p["attn_out_norm"] = jnp.ones((D,), dtype)
        p["ssm_out_norm"] = jnp.ones((D,), dtype)
    p["mlp_norm"] = jnp.ones((D,), dtype)
    if arch.family == "moe":
        p["moe"] = moe_lib.init_moe_params(ks[2], D, arch.moe, dtype)
    else:
        p["mlp"] = _init_mlp(ks[3], arch, dtype)
    return p


def init_params(key: jax.Array, arch: ArchConfig,
                dtype=jnp.float32) -> Params:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    V, D, L = arch.vocab, arch.d_model, arch.n_layers
    layer_keys = jax.random.split(k_layers, L)
    layers = jax.vmap(lambda k: _init_layer(k, arch, dtype))(layer_keys)
    p = {
        "embed": (jax.random.normal(k_embed, (V, D)) * 0.02).astype(dtype),
        "layers": layers,
        "final_norm": jnp.ones((D,), dtype),
    }
    if arch.family == "audio":
        p["lm_head"] = (jax.random.normal(k_head, (arch.n_codebooks, D, V))
                        * D ** -0.5).astype(dtype)
    elif not arch.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k_head, (D, V)) * D ** -0.5
                        ).astype(dtype)
    return p


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn_apply(p, x, positions, arch: ArchConfig, kv_override=None,
                decode_cache=None, pos_scalar=None, kv_prefix=None):
    """Full attention path.  Returns (out, (k, v)) for cache construction.

    kv_prefix: optional (k_pre, v_pre, pre_positions) — already-computed
    (RoPE-rotated) K/V of a shared prompt prefix; queries attend the prefix
    plus themselves (chunked prefill for the prefix-sharing admission path).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if arch.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if arch.mrope:
        q = apply_mrope(q, positions, arch.rope_theta)
        k = apply_mrope(k, positions, arch.rope_theta)
        pos_1d = positions[..., 0]
    else:
        q = apply_rope(q, positions, arch.rope_theta)
        k = apply_rope(k, positions, arch.rope_theta)
        pos_1d = positions
    if decode_cache is not None:
        k_cache, v_cache = decode_cache
        out = decode_attention(q, k_cache, v_cache, pos_scalar,
                               window=arch.sliding_window)
    elif kv_prefix is not None:
        k_pre, v_pre, pre_pos = kv_prefix
        k_all = jnp.concatenate([k_pre.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([v_pre.astype(v.dtype), v], axis=1)
        kv_pos = jnp.concatenate([pre_pos, pos_1d], axis=1)
        out = flash_attention(q, k_all, v_all, pos_1d, kv_pos, causal=True,
                              window=arch.sliding_window)
    else:
        out = flash_attention(q, k, v, pos_1d, pos_1d, causal=True,
                              window=arch.sliding_window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def _block_train(p, x, positions, arch: ArchConfig, kv_prefix=None):
    """One layer, training/prefill mode.  Returns (x, aux, (k, v), ssm_state,
    conv_tail) — cache parts are None where inapplicable."""
    aux = jnp.float32(0.0)
    kv = ssm_state = conv_tail = None
    if arch.family == "ssm":
        h, ssm_state, conv_tail = ssm_lib.ssd_chunked(
            p["ssm"], rms_norm(x, p["ssm_norm"]), arch.ssm)
        return x + h, aux, kv, ssm_state, conv_tail

    normed = rms_norm(x, p["attn_norm"])
    attn_out, kv = _attn_apply(p["attn"], normed, positions, arch,
                               kv_prefix=kv_prefix)
    if arch.family == "hybrid":
        ssm_out, ssm_state, conv_tail = ssm_lib.ssd_chunked(
            p["ssm"], normed, arch.ssm)
        mixed = 0.5 * (rms_norm(attn_out, p["attn_out_norm"])
                       + rms_norm(ssm_out, p["ssm_out_norm"]))
        x = x + mixed
    else:
        x = x + attn_out

    normed2 = rms_norm(x, p["mlp_norm"])
    if arch.family == "moe":
        mlp_out, aux = moe_lib.moe_block(p["moe"], normed2, arch.moe)
    elif arch.mlp_gated:
        mlp_out = swiglu(p["mlp"], normed2)
    else:
        mlp_out = gelu_mlp(p["mlp"], normed2)
    return x + mlp_out, aux, kv, ssm_state, conv_tail


def _block_decode(p, x, cache_layer, pos, arch: ArchConfig, kv_hook=None):
    """One layer, single-token decode.  cache_layer is this layer's slice.

    ``pos`` is a scalar position, or a ragged (B,) vector of per-sequence
    positions (the continuous-batching slot pool).  Returns
    (h, new_cache, q) — q is this layer's rotated query (attention
    families; None for ssm), used by the serving engine's tier scoring.

    ``kv_hook(q, k, v, cache_layer) -> (attn, cache_updates)``: optional
    override of the KV write + attend section (rotated q/k/v in, attention
    output out) — the fused paged tier routes through here
    (``paged_decode_step``) while RoPE/MLP/norm stay shared.
    """
    new_cache = dict(cache_layer)
    ragged = jnp.asarray(pos).ndim == 1
    if ragged:
        positions = pos[:, None]
        if arch.mrope:
            positions = jnp.broadcast_to(positions[..., None],
                                         (x.shape[0], 1, 3))
    else:
        positions = jnp.broadcast_to(pos, (x.shape[0], 1))
        if arch.mrope:
            positions = jnp.broadcast_to(pos, (x.shape[0], 1, 3))

    if arch.family == "ssm":
        h, state, conv = ssm_lib.ssd_decode_step(
            p["ssm"], rms_norm(x, p["ssm_norm"]),
            cache_layer["ssm"], cache_layer["conv"], arch.ssm)
        new_cache.update(ssm=state, conv=conv)
        return x + h, new_cache, None

    normed = rms_norm(x, p["attn_norm"])
    # write the new token's K/V into the cache slot, then attend
    q = jnp.einsum("bsd,dhk->bshk", normed, p["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", normed, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", normed, p["attn"]["wv"])
    if arch.qk_norm:
        q = rms_norm(q, p["attn"]["q_norm"])
        k = rms_norm(k, p["attn"]["k_norm"])
    if arch.mrope:
        q = apply_mrope(q, positions, arch.rope_theta)
        k = apply_mrope(k, positions, arch.rope_theta)
    else:
        q = apply_rope(q, positions, arch.rope_theta)
        k = apply_rope(k, positions, arch.rope_theta)
    if kv_hook is not None:
        out, cache_updates = kv_hook(q, k, v, cache_layer)
        new_cache.update(cache_updates)
    else:
        T = cache_layer["k"].shape[1]
        slot = pos % T if arch.sliding_window else jnp.minimum(pos, T - 1)
        if ragged:
            b_idx = jnp.arange(x.shape[0])
            k_cache = cache_layer["k"].at[b_idx, slot].set(k[:, 0])
            v_cache = cache_layer["v"].at[b_idx, slot].set(v[:, 0])
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_layer["k"], k, slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache_layer["v"], v, slot, 1)
        new_cache.update(k=k_cache, v=v_cache)
        out = decode_attention(q, k_cache, v_cache, pos,
                               window=arch.sliding_window)
    attn_out = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])

    if arch.family == "hybrid":
        ssm_out, state, conv = ssm_lib.ssd_decode_step(
            p["ssm"], normed, cache_layer["ssm"], cache_layer["conv"], arch.ssm)
        new_cache.update(ssm=state, conv=conv)
        mixed = 0.5 * (rms_norm(attn_out, p["attn_out_norm"])
                       + rms_norm(ssm_out, p["ssm_out_norm"]))
        x = x + mixed
    else:
        x = x + attn_out

    normed2 = rms_norm(x, p["mlp_norm"])
    if arch.family == "moe":
        # decode: tiny token counts => lossless capacity (no token dropping)
        mlp_out, _ = moe_lib.moe_block(p["moe"], normed2, arch.moe,
                                       group_size=x.shape[0], no_drop=True)
    elif arch.mlp_gated:
        mlp_out = swiglu(p["mlp"], normed2)
    else:
        mlp_out = gelu_mlp(p["mlp"], normed2)
    return x + mlp_out, new_cache, q


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch: dict, arch: ArchConfig) -> jax.Array:
    if arch.family == "audio":
        return batch["frame_embeds"]
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if arch.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        n_patch = pe.shape[1]
        x = jnp.concatenate([pe, x[:, n_patch:]], axis=1)
    return x


def _positions_for(batch: dict, arch: ArchConfig, seq: int, bsz: int):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (bsz, seq))
    if arch.mrope:
        pos = jnp.broadcast_to(pos[..., None], (bsz, seq, 3))
    return pos


def _lm_logits(params, x, arch: ArchConfig):
    if arch.family == "audio":
        return jnp.einsum("bsd,kdv->bskv", x, params["lm_head"])
    head = params.get("lm_head", params["embed"].T)
    return jnp.einsum("bsd,dv->bsv", x, head)


def forward(params: Params, batch: dict, arch: ArchConfig,
            remat: str = "full", compute_dtype=jnp.bfloat16):
    """Training/scoring forward: returns (logits, aux_loss)."""
    x = _embed_inputs(params, batch, arch).astype(compute_dtype)
    x = ctx.constrain(x, ctx.BATCH, ctx.SEQ, None)
    B, S = x.shape[:2]
    positions = _positions_for(batch, arch, S, B)

    cparams = jax.tree.map(
        lambda a: a.astype(compute_dtype)
        if a.dtype == jnp.float32 and a.ndim > 1 else a, params["layers"])

    def body(carry, layer_params):
        h, aux = carry
        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        h, aux_l, *_ = _block_train(layer_params, h, positions, arch)
        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        return (h, aux + aux_l), None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), cparams)
    x = rms_norm(x, params["final_norm"].astype(compute_dtype))
    logits = _lm_logits(params, x, arch)
    logits = ctx.constrain(logits, ctx.BATCH,
                           *([None] * (logits.ndim - 2)), ctx.MODEL)
    return logits, aux / max(arch.n_layers, 1)


def loss_fn(params: Params, batch: dict, arch: ArchConfig,
            remat: str = "full", aux_weight: float = 0.01):
    logits, aux = forward(params, batch, arch, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(nll))
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


# -- cache ---------------------------------------------------------------------

def init_cache(arch: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Cache:
    """Decode cache pytree; leaves have a leading L axis for the layer scan."""
    L = arch.n_layers
    c: Cache = {"pos": jnp.zeros((), jnp.int32)}
    if arch.n_heads:
        T = min(max_len, arch.sliding_window) if arch.sliding_window else max_len
        hd = arch.resolved_head_dim
        c["k"] = jnp.zeros((L, batch, T, arch.n_kv_heads, hd), dtype)
        c["v"] = jnp.zeros((L, batch, T, arch.n_kv_heads, hd), dtype)
    if arch.ssm is not None:
        s = arch.ssm
        c["ssm"] = jnp.zeros((L, batch, s.n_heads, s.head_dim, s.d_state),
                             jnp.float32)
        c["conv"] = (
            jnp.zeros((L, batch, s.d_conv - 1, ssm_lib.d_inner(s)), dtype),
            jnp.zeros((L, batch, s.d_conv - 1, 2 * s.d_state), dtype))
    return c


def prefill(params: Params, batch: dict, arch: ArchConfig, max_len: int,
            compute_dtype=jnp.bfloat16, prefix_kv=None):
    """Process a prompt, returning (logits, cache ready for decode).

    prefix_kv: optional (k_pre, v_pre) of shape (L, B, T_pre, Hkv, hd) —
    already-computed K/V of a shared prompt prefix (the paged far pool's
    copy).  Only the *suffix* in ``batch`` is computed; its queries attend
    prefix + suffix, and the returned cache holds prefix followed by suffix
    rows — exactly the cache a full prefill of prefix+suffix would produce,
    at suffix cost.  ``batch["positions"]`` must then carry the suffix's
    absolute positions (T_pre + arange(S)); logits cover the suffix only.
    """
    x = _embed_inputs(params, batch, arch).astype(compute_dtype)
    x = ctx.constrain(x, ctx.BATCH, ctx.SEQ, None)
    B, S = x.shape[:2]
    positions = _positions_for(batch, arch, S, B)
    cache = init_cache(arch, B, max_len, compute_dtype)

    cparams = jax.tree.map(
        lambda a: a.astype(compute_dtype)
        if a.dtype == jnp.float32 and a.ndim > 1 else a, params["layers"])

    t_pre = 0
    if prefix_kv is not None:
        assert arch.n_heads and arch.ssm is None and not arch.sliding_window, \
            "prefix-chunked prefill needs a plain-attention architecture"
        k_pre, v_pre = prefix_kv
        t_pre = k_pre.shape[2]
        pre_pos = jnp.broadcast_to(jnp.arange(t_pre, dtype=jnp.int32),
                                   (B, t_pre))
        xs = (cparams, k_pre.astype(compute_dtype),
              v_pre.astype(compute_dtype))
    else:
        xs = (cparams, None, None)

    def body(h, scanned):
        layer_params, k_pre_l, v_pre_l = scanned
        kv_prefix = None if k_pre_l is None \
            else (k_pre_l, v_pre_l, pre_pos)
        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        h, _, kv, ssm_state, conv_tail = _block_train(
            layer_params, h, positions, arch, kv_prefix=kv_prefix)
        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        outs = {}
        if kv is not None:
            k, v = kv
            if k_pre_l is not None:
                k = jnp.concatenate([k_pre_l.astype(k.dtype), k], axis=1)
                v = jnp.concatenate([v_pre_l.astype(v.dtype), v], axis=1)
            written = k.shape[1]
            T = cache["k"].shape[2]
            if arch.sliding_window and written > T:
                # Keep the last `window` tokens, rotated into ring order.
                k, v = k[:, -T:], v[:, -T:]
                shift = written % T
                k = jnp.roll(k, shift, axis=1)
                v = jnp.roll(v, shift, axis=1)
            elif written < T:
                k = jnp.pad(k, ((0, 0), (0, T - written), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, T - written), (0, 0), (0, 0)))
            outs["k"], outs["v"] = k, v
        if ssm_state is not None:
            outs["ssm"] = ssm_state
            outs["conv"] = conv_tail
        return h, outs

    x, stacked = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["final_norm"].astype(compute_dtype))
    logits = _lm_logits(params, x, arch)
    logits = ctx.constrain(logits, ctx.BATCH,
                           *([None] * (logits.ndim - 2)), ctx.MODEL)
    cache = {**cache, **stacked, "pos": jnp.asarray(t_pre + S, jnp.int32)}
    return logits, cache


def decode_step(params: Params, cache: Cache, batch: dict, arch: ArchConfig,
                compute_dtype=jnp.bfloat16, want_aux: bool = False):
    """One decode step.  batch['tokens']: (B, 1) (or frame_embeds (B,1,D)).

    ``cache['pos']`` may be a scalar (whole batch at one position) or a
    ragged (B,) vector of per-sequence positions (continuous-batching slot
    pools; each sequence attends its own live prefix and writes its K/V at
    its own slot).

    Returns (logits (B,1,V...), new cache); with ``want_aux=True`` also a
    third aux dict with ``q0`` — layer-0's rotated query (B,H,hd), the
    probe the tiered-KV scoring pass uses (attention families only)."""
    x = _embed_inputs(params, batch, arch).astype(compute_dtype)
    x = ctx.constrain(x, ctx.BATCH, ctx.SEQ, None)
    pos = cache["pos"]

    cparams = jax.tree.map(
        lambda a: a.astype(compute_dtype)
        if a.dtype == jnp.float32 and a.ndim > 1 else a, params["layers"])

    layer_cache = {k: v for k, v in cache.items() if k != "pos"}

    def body(h, scanned):
        layer_params, cl = scanned
        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        h, new_cl, q = _block_decode(layer_params, h, cl, pos, arch)
        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        return h, (new_cl, q if want_aux else None)

    x, (new_layer_cache, qs) = jax.lax.scan(body, x, (cparams, layer_cache))
    x = rms_norm(x, params["final_norm"].astype(compute_dtype))
    logits = _lm_logits(params, x, arch)
    logits = ctx.constrain(logits, ctx.BATCH,
                           *([None] * (logits.ndim - 2)), ctx.MODEL)
    new_cache = {**new_layer_cache, "pos": pos + 1}
    if want_aux:
        aux = {"q0": qs[0][:, 0].astype(jnp.float32)} if qs is not None else {}
        return logits, new_cache, aux
    return logits, new_cache


def paged_decode_step(params: Params, cache: Cache, batch: dict,
                      arch: ArchConfig, meta: dict,
                      compute_dtype=jnp.bfloat16, want_aux: bool = False,
                      fused: bool = True, mesh=None):
    """One decode step over the paged tier — the pool is the ONLY KV store.

    Identical math to ``decode_step`` — every layer attends its slot's full
    live prefix — but the per-layer shared page pool is the single source
    of truth (ISSUE 5): the new token's K/V is written through the page
    table into the pool (``append_pid``/``append_off``; sentinel drops) and
    NOWHERE else — the dense per-slot master rows of the PR-4 path are
    gone.  Two read paths over the same pool bytes:

      fused=True  : the page-table-walking kernel (`kernels.paged_attention`)
                    over pool + per-layer global near buffer — touches only
                    each slot's live, non-promoted far pages.
      fused=False : materialize the slot's far view from the pool per layer
                    and run the same ``decode_attention`` reduction the
                    PR-4 dense-master path ran — bit-identical logits to
                    it, since the pool holds bit-identical bytes (the
                    oracle leg of the fused-vs-dense token-parity pin).

    ``cache`` carries:

      pool_k/pool_v : (L, P, page, Hkv, hd)  per-layer shared far pool
      near_k/near_v : (L, C*page, Hkv, hd)   per-layer global near buffer
                                             (read only by the fused path)

    ``meta`` is ``core.tiered_kv.paged_step_metadata(state, pos + 1,
    cfg, append_pos=pos)`` — computed ONCE per step by the engine and shared
    by every layer (lengths = pos + 1 so the token appended this step is
    attended, matching ``decode_attention``'s ``slot <= pos`` mask).

    ``mesh``: pool/near buffers KV-HEAD-SHARDED over the 'model' axis
    (docs/design.md §2h).  The append scatter indexes only (page, offset)
    dims, so it partitions under GSPMD with exact semantics; the fused read
    runs per head shard under ``shard_map`` and hands back replicated
    stats; the dense read computes per-head stats under GSPMD (head-local
    math — no collective can reorder it) and the attention output is
    CONSTRAINED replicated before the wo projection, so the cross-head
    contraction always reduces the full head dim in single-device order —
    the bit-identity pin.  Emitted tokens are bit-identical to the
    single-device step in both modes (tests/test_mesh_serving.py).

    Returns (logits, new_cache[, aux]) like ``decode_step``.
    """
    assert arch.n_heads and arch.ssm is None and not arch.sliding_window, \
        "paged decode requires a plain-attention architecture"
    from repro.sharding.specs import kv_shard_count
    if mesh is not None and kv_shard_count(mesh, arch.n_kv_heads) == 1:
        mesh = None                   # GQA/MQA fallback: fully replicated
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P_
        _pool_ns = NamedSharding(mesh, P_(None, None, "model"))
        _repl_ns = NamedSharding(mesh, P_())
    x = _embed_inputs(params, batch, arch).astype(compute_dtype)
    x = ctx.constrain(x, ctx.BATCH, ctx.SEQ, None)
    pos = cache["pos"]
    if jnp.asarray(pos).ndim == 0:
        pos = jnp.broadcast_to(pos, (x.shape[0],))
    B = x.shape[0]

    cparams = jax.tree.map(
        lambda a: a.astype(compute_dtype)
        if a.dtype == jnp.float32 and a.ndim > 1 else a, params["layers"])
    # the near buffers are read-only per step: scan them as inputs but keep
    # them OUT of the per-layer cache so the scan does not stack an
    # untouched copy of both buffers every decode step
    layer_cache = {k: v for k, v in cache.items()
                   if k not in ("pos", "near_k", "near_v")}

    def body(h, scanned):
        layer_params, cl, nk, nv = scanned

        def kv_hook(q, k, v, cl2):
            pool_k = cl2["pool_k"].at[meta["append_pid"],
                                      meta["append_off"]].set(k[:, 0],
                                                              mode="drop")
            pool_v = cl2["pool_v"].at[meta["append_pid"],
                                      meta["append_off"]].set(v[:, 0],
                                                              mode="drop")
            if mesh is not None:
                # keep the appended pool head-sharded (the scatter touches
                # only page/offset dims — GSPMD must not drift the pool to
                # replicated across steps)
                pool_k = jax.lax.with_sharding_constraint(pool_k, _pool_ns)
                pool_v = jax.lax.with_sharding_constraint(pool_v, _pool_ns)
            if fused:
                out = paged_decode_attention(q, pool_k, pool_v, nk, nv,
                                             meta, mesh=mesh)
            else:
                n_pages = meta["pt"].shape[1]
                safe = jnp.maximum(meta["pt"], 0)
                _, page, Hkv, hd = pool_k.shape
                k_view = pool_k[safe].reshape(B, n_pages * page, Hkv, hd)
                v_view = pool_v[safe].reshape(B, n_pages * page, Hkv, hd)
                out = decode_attention(q, k_view, v_view, pos)
                if mesh is not None:
                    # per-head stats are exact under GSPMD (no op crosses
                    # heads); replicate them HERE so the wo contraction
                    # reduces the full head dim in single-device order
                    # instead of a GSPMD partial-sum psum — the dense
                    # path's bit-identity pin
                    out = jax.lax.with_sharding_constraint(out, _repl_ns)
            return out, dict(pool_k=pool_k, pool_v=pool_v)

        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        h, new_cl, q = _block_decode(layer_params, h, cl, pos, arch,
                                     kv_hook=kv_hook)
        h = ctx.constrain(h, ctx.BATCH, ctx.SEQ, None)
        return h, (new_cl, q if want_aux else None)

    x, (new_layer_cache, qs) = jax.lax.scan(
        body, x, (cparams, layer_cache, cache["near_k"], cache["near_v"]))
    x = rms_norm(x, params["final_norm"].astype(compute_dtype))
    logits = _lm_logits(params, x, arch)
    logits = ctx.constrain(logits, ctx.BATCH,
                           *([None] * (logits.ndim - 2)), ctx.MODEL)
    new_cache = {**new_layer_cache, "near_k": cache["near_k"],
                 "near_v": cache["near_v"], "pos": cache["pos"] + 1}
    if want_aux:
        aux = {"q0": qs[0][:, 0].astype(jnp.float32)}
        return logits, new_cache, aux
    return logits, new_cache
