"""Continuous-batching tiered-KV serving engine (the TL-DRAM runtime).

The paper's near segment only pays off when many concurrent accesses share
the fast path; the serving analogue is a *slot pool*: a fixed batch of
decode slots that independent sequences are admitted into and retired from,
so one batched decode step serves every in-flight sequence at once (ragged
``pos`` — each slot sits at its own position), while the unified
`repro.tier` engine migrates each slot's hot KV pages into the near tier on
a background cadence.

Scheduler loop (``ServingEngine.run``):

  admit    : pop arrived requests into free slots — prefill (bucketed jit)
             into the slot's rows of the pool cache, seed the first token.
  decode   : ONE batched ``transformer.decode_step`` with per-slot ``pos``
             (ragged state threaded through RoPE, cache scatter and the
             attention mask) emits a token for every active slot.
  maintain : every ``tier.interval`` steps, score per-page attention mass
             with the step's layer-0 queries and run the configured policy
             (SC/WMC/BBC via ``plan_and_migrate``; STATIC pins each slot
             once at its first interval) — the amortized IST.
  retire   : finished sequences free their slot (tier state reset so the
             next tenant inherits nothing); the slot is reused.

The decode path is *exact* (full-cache attention with ragged masks), so
emitted tokens match the single-sequence ``greedy_generate`` reference
bit-for-bit; the tiered state drives the byte-cost model and, optionally, a
read-path verification probe (``verify_tiered_read``).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import tiered_kv as tkv
from repro.core.tiered_kv import TieredKVConfig
from repro.kernels import ref
from repro.models import transformer
from repro.serve.metrics import CostModel, ServingReport
from repro.serve.trace import Request


@dataclass
class ServingConfig:
    n_slots: int = 4
    max_len: int = 256
    prefill_bucket: int = 32      # prompt lengths pad up to a multiple of
                                  # this (bounds jit recompiles; exact —
                                  # causal attention ignores the pad tail)
    tier: TieredKVConfig = field(default_factory=TieredKVConfig)
    cost: CostModel = field(default_factory=CostModel)
    verify_tiered_read: bool = False   # probe tiered read vs monolithic
                                       # attention at every planning pass


@dataclass
class _Slot:
    req: Request
    emitted: list
    last_emit: float              # modeled clock of the last emitted token


class ServingEngine:
    def __init__(self, params, arch: ArchConfig, cfg: ServingConfig):
        assert arch.n_heads and arch.ssm is None, \
            "serving engine requires an attention-family architecture"
        assert not arch.sliding_window, \
            "ragged slot pool + ring buffer not supported yet"
        assert cfg.max_len % cfg.tier.page == 0, \
            "max_len must be a page multiple"
        self.params, self.arch, self.cfg = params, arch, cfg
        self._decode = jax.jit(
            lambda p, c, b: transformer.decode_step(p, c, b, arch,
                                                    want_aux=True))
        self._plan = jax.jit(
            lambda c, q, pos, idle, m: tkv.plan_and_migrate(
                c, q, pos, cfg.tier, idle=idle, masses=m))
        self._masses = jax.jit(
            lambda q, c, pos: tkv.page_masses(q, c, pos, cfg.tier))
        # jax.jit caches per input shape, so one wrapper covers every
        # prompt-length bucket
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, arch,
                                             max_len=cfg.max_len))

    def _admit(self, req: Request, slot: int, clock: float) -> float:
        cfg = self.cfg
        S = int(req.prompt.shape[0])
        assert S + req.max_new_tokens <= cfg.max_len, \
            f"request {req.rid} does not fit max_len={cfg.max_len}"
        s_pad = -(-S // cfg.prefill_bucket) * cfg.prefill_bucket
        padded = np.zeros((1, s_pad), np.int32)
        padded[0, :S] = req.prompt
        logits, pcache = self._prefill(self.params, {"tokens": padded})
        first = int(jnp.argmax(logits[0, S - 1]))
        # write the sequence's K/V rows into the pool (positions >= S are
        # zero-padded by prefill and masked by the ragged live mask)
        self.cache["k"] = self.cache["k"].at[:, slot].set(pcache["k"][:, 0])
        self.cache["v"] = self.cache["v"].at[:, slot].set(pcache["v"][:, 0])
        self.pos[slot] = S
        self.tok[slot] = first
        self._static_pinned[slot] = False
        clock += cfg.cost.prefill_cost(S)
        self.slots[slot] = _Slot(req=req, emitted=[first], last_emit=clock)
        self.report.token_latencies.append(
            clock - self._visible_clock[req.rid])
        self.report.tokens += 1
        self.slot_history.setdefault(slot, []).append(req.rid)
        return clock

    def _retire(self, slot: int):
        st = self.slots[slot]
        self.report.outputs[st.req.rid] = list(st.emitted)
        self.slots[slot] = None
        self.pos[slot] = 0
        self.tok[slot] = 0
        self._near_tokens[slot] = 0
        # clear tier state NOW, not at the next admit: the dead tenant's
        # decayed scores would otherwise stay promotion-eligible and keep
        # the planning pass migrating (and billing) its stale pages
        self.tiered = tkv.reset_sequences(
            self.tiered, jnp.arange(self.cfg.n_slots) == slot)
        self.free.append(slot)
        self.free.sort()

    # -- background tier maintenance ----------------------------------------

    def _maintain(self, q0, clock: float, idle: bool) -> float:
        cfg = self.cfg
        tier = cfg.tier
        active = np.array([s is not None for s in self.slots])
        self.tiered["far_k"] = self.cache["k"][0]
        self.tiered["far_v"] = self.cache["v"][0]
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        # one scoring pass per interval: page_masses reads only the far
        # master copy (migration never changes it), so the same masses
        # drive planning/pinning AND the hit-mass metric below
        masses_dev = self._masses(q0, self.tiered, pos_vec)
        if tier.policy.upper() == "STATIC":
            need = jnp.asarray(active & ~self._static_pinned)
            if bool(need.any()):
                self.tiered = tkv.preload_static_kv(
                    self.tiered, masses_dev, pos_vec, tier, row_mask=need)
                moved = int(np.asarray(
                    self.tiered["page_of_slot"] >= 0)[np.asarray(need)].sum())
                clock += cfg.cost.migration_cost(moved, tier.page)
                self.report.migrations += moved   # pin copies are ISTs too
                self._static_pinned |= np.asarray(need)
        else:
            before = int(self.tiered["migrations"])
            self.tiered = self._plan(self.tiered, q0, pos_vec, idle,
                                     masses_dev)
            moved = int(self.tiered["migrations"]) - before
            clock += cfg.cost.migration_cost(moved, tier.page)
            self.report.migrations += moved
        occupied = np.asarray(self.tiered["page_of_slot"] >= 0)
        self._near_tokens = occupied.sum(axis=1) * tier.page
        # near-tier hit mass over active slots (the paper's near-segment
        # hit rate, in attention-mass units)
        if active.any():
            masses = np.asarray(masses_dev)
            promoted = np.asarray(self.tiered["slot_of_page"] >= 0)
            tot = masses[active].sum()
            if tot > 0:
                self.report.near_hit_mass.append(
                    float((masses * promoted)[active].sum() / tot))
            if cfg.verify_tiered_read:
                got = tkv.tiered_attention(self.tiered, q0, pos_vec, tier)
                want = ref.decode_attention_ref(
                    q0[:, None], self.tiered["far_k"], self.tiered["far_v"],
                    pos_vec)[:, 0]
                err = float(jnp.max(jnp.abs(
                    (got - want)[jnp.asarray(active)])))
                self.report.max_read_err = max(self.report.max_read_err, err)
        return clock

    # -- driver --------------------------------------------------------------

    def run(self, trace: list[Request], scenario: str = "trace") -> ServingReport:
        """Replay an offline arrival trace to completion."""
        cfg = self.cfg
        self.report = ServingReport(scenario=scenario,
                                    policy=cfg.tier.policy,
                                    n_requests=len(trace))
        self.cache = transformer.init_cache(self.arch, cfg.n_slots,
                                            cfg.max_len)
        self.tiered = tkv.init_tiered_cache(self.cache["k"][0],
                                            self.cache["v"][0], cfg.tier)
        self.pos = np.zeros(cfg.n_slots, np.int64)
        self.tok = np.zeros(cfg.n_slots, np.int64)
        self.slots: list[_Slot | None] = [None] * cfg.n_slots
        self.free = list(range(cfg.n_slots))
        self.slot_history = {}
        self._near_tokens = np.zeros(cfg.n_slots, np.int64)
        self._static_pinned = np.zeros(cfg.n_slots, bool)
        self._visible_clock: dict[int, float] = {}

        queue = deque(sorted(trace, key=lambda r: (r.arrival, r.rid)))
        tick, clock, steps = 0, 0.0, 0
        t0 = time.perf_counter()
        while queue or any(s is not None for s in self.slots):
            for req in queue:                  # sorted by arrival: stop early
                if req.arrival > tick:
                    break
                if req.rid not in self._visible_clock:
                    self._visible_clock[req.rid] = clock
            while queue and queue[0].arrival <= tick and self.free:
                clock = self._admit(queue.popleft(), self.free.pop(0), clock)
            # a request may want exactly the prefill token (max_new_tokens=1)
            for b in range(cfg.n_slots):
                st = self.slots[b]
                if st is not None and len(st.emitted) >= st.req.max_new_tokens:
                    self._retire(b)
            active_idx = [b for b, s in enumerate(self.slots) if s is not None]
            if not active_idx:
                if queue:
                    tick = max(tick + 1, queue[0].arrival)  # idle fast-forward
                continue

            self.cache["pos"] = jnp.asarray(self.pos, jnp.int32)
            logits, new_cache, aux = self._decode(
                self.params, self.cache, {"tokens": jnp.asarray(
                    self.tok[:, None], jnp.int32)})
            self.cache = new_cache
            toks = np.asarray(jnp.argmax(logits, axis=-1))[:, 0]

            live = self.pos[active_idx] + 1
            clock += cfg.cost.decode_step_cost(
                self._near_tokens[active_idx], live)
            steps += 1
            for b in active_idx:
                st = self.slots[b]
                st.emitted.append(int(toks[b]))
                self.report.token_latencies.append(clock - st.last_emit)
                st.last_emit = clock
                self.report.tokens += 1
                self.pos[b] += 1
                self.tok[b] = int(toks[b])
                if len(st.emitted) >= st.req.max_new_tokens:
                    self._retire(b)
            if steps % cfg.tier.interval == 0:
                idle = not (queue and queue[0].arrival <= tick)
                clock = self._maintain(aux["q0"], clock, idle)
            tick += 1

        self.report.steps = steps
        self.report.wall_s = time.perf_counter() - t0
        self.report.modeled_time = clock
        self.report.slot_history = dict(self.slot_history)
        return self.report


def sequential_baseline(params, arch: ArchConfig, trace: list[Request],
                        cfg: ServingConfig,
                        scenario: str = "trace") -> ServingReport:
    """The no-batching reference: each request served to completion by
    single-sequence ``greedy_generate`` (B=1), one after another, under the
    same modeled cost landscape (no near tier: every live KV token is
    gather-addressed at ``far_cost``)."""
    from repro.launch.serve import greedy_generate, make_decode_step
    report = ServingReport(scenario=scenario, policy="sequential",
                           n_requests=len(trace))
    step_fn = jax.jit(make_decode_step(arch))
    prefill_fn = jax.jit(
        lambda p, b: transformer.prefill(p, b, arch, max_len=cfg.max_len))
    clock = 0.0
    t0 = time.perf_counter()
    for req in sorted(trace, key=lambda r: (r.arrival, r.rid)):
        toks, _ = greedy_generate(
            params, arch, {"tokens": np.asarray(req.prompt)[None]},
            steps=req.max_new_tokens, max_len=cfg.max_len, step_fn=step_fn,
            prefill_fn=prefill_fn)
        report.outputs[req.rid] = np.asarray(toks)[0].tolist()
        S = int(req.prompt.shape[0])
        clock += cfg.cost.prefill_cost(S)
        last = clock
        report.tokens += 1
        report.token_latencies.append(0.0)   # no queueing modeled: TTFT = 0
        for i in range(1, req.max_new_tokens):
            clock += cfg.cost.decode_step_cost(np.zeros(1),
                                               np.asarray([S + i]))
            report.token_latencies.append(clock - last)
            last = clock
            report.tokens += 1
        report.steps += req.max_new_tokens - 1
    report.wall_s = time.perf_counter() - t0
    report.modeled_time = clock
    return report
