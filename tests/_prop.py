"""Seeded property-test harness: a hypothesis-compatible micro-subset.

This container (and the CI no-hypothesis matrix leg) has no ``hypothesis``,
so the property suites in ``test_kernels.py`` / ``test_policies.py`` used to
silently skip via ``importorskip``.  This module provides the tiny slice of
the hypothesis API those suites actually use — ``given``/``settings``
decorators plus ``integers``/``booleans``/``lists``/``tuples``/
``sampled_from`` strategies — backed by a deterministic seeded generator, so
the properties run everywhere.  Import pattern (hypothesis stays the
preferred fast path when installed — it shrinks better and caches failures):

    try:
        from hypothesis import given, settings
        import hypothesis.strategies as st
    except ImportError:                      # seeded fallback harness
        from _prop import given, settings, strategies as st

Failures are greedily shrunk (smaller ints, shorter lists) before reporting;
the minimal case and its draw index are embedded in the raised error so a
run can be reproduced by eye.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

_DEFAULT_MAX_EXAMPLES = 50
_SHRINK_BUDGET = 200          # max extra executions spent minimizing a failure


class Strategy:
    """Base: draw an example from a seeded rng; yield simpler candidates."""

    def example(self, rng: random.Random):
        raise NotImplementedError

    def simpler(self, value):
        """Yield candidate replacements, simplest first (may be empty)."""
        return iter(())


class _Integers(Strategy):
    def __init__(self, min_value, max_value):
        self.min_value, self.max_value = int(min_value), int(max_value)

    def example(self, rng):
        return rng.randint(self.min_value, self.max_value)

    def simpler(self, value):
        lo = self.min_value
        for cand in (lo, (lo + value) // 2, value - 1):
            if lo <= cand < value:
                yield cand


class _Booleans(Strategy):
    def example(self, rng):
        return rng.random() < 0.5

    def simpler(self, value):
        if value:
            yield False


class _SampledFrom(Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng):
        return rng.choice(self.elements)

    def simpler(self, value):
        if self.elements and value != self.elements[0]:
            yield self.elements[0]


class _Lists(Strategy):
    def __init__(self, elements: Strategy, min_size=0, max_size=None):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size) if max_size is not None \
            else self.min_size + 32

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(n)]

    def simpler(self, value):
        n = len(value)
        if n > self.min_size:
            half = max(self.min_size, n // 2)
            if half < n:
                yield value[:half]
            yield value[:n - 1]
            yield value[1:]


class _Tuples(Strategy):
    def __init__(self, *elements):
        self.elements = elements

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elements)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` for the used subset."""

    @staticmethod
    def integers(min_value=0, max_value=2 ** 16):
        return _Integers(min_value, max_value)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements, min_size=0, max_size=None, **_):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def tuples(*elements):
        return _Tuples(*elements)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Accepts (and mostly ignores) hypothesis settings; records
    ``max_examples`` for a ``given`` applied above it."""

    def deco(fn):
        fn._prop_settings = {"max_examples": int(max_examples)}
        return fn

    return deco


def _run(fn, args, kw, case):
    try:
        fn(*args, **case, **kw)
        return None
    except Exception as e:                    # noqa: BLE001 — reported upward
        return e


def _shrink(fn, args, kw, strats, case):
    """Greedy minimization: try simpler values one kwarg at a time until no
    candidate still fails (bounded by _SHRINK_BUDGET executions)."""
    budget = _SHRINK_BUDGET
    improved = True
    while improved and budget > 0:
        improved = False
        for name, strat in strats.items():
            for cand in strat.simpler(case[name]):
                if budget <= 0:
                    break
                budget -= 1
                trial = dict(case, **{name: cand})
                if _run(fn, args, kw, trial) is not None:
                    case = trial
                    improved = True
                    break
    return case


def given(*pos, **strats):
    """Decorator: run the test for ``max_examples`` deterministic seeded
    cases drawn from keyword strategies (positional strategies unsupported —
    the suites here always bind by name, as hypothesis recommends)."""
    assert not pos, "_prop.given supports keyword strategies only"

    def deco(fn):
        base = zlib.crc32(fn.__qualname__.encode("utf-8"))

        @functools.wraps(fn)
        def wrapper(*args, **kw):
            # Read at call time: @settings may sit either below @given (set
            # on fn) or above it (set on this wrapper) — both orders are
            # valid with real hypothesis and must behave the same here.
            max_examples = getattr(
                wrapper, "_prop_settings",
                getattr(fn, "_prop_settings", {})).get(
                    "max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(max_examples):
                rng = random.Random(base * 1_000_003 + i)
                case = {k: s.example(rng) for k, s in strats.items()}
                err = _run(fn, args, kw, case)
                if err is not None:
                    minimal = _shrink(fn, args, kw, strats, case)
                    raise AssertionError(
                        f"property {fn.__qualname__} failed (draw #{i}); "
                        f"minimal failing case: {minimal!r}") from err

        # Hide the strategy-bound parameters from pytest's fixture
        # resolution (hypothesis does the same): the wrapper's visible
        # signature keeps only untouched parameters like ``self``.
        sig = inspect.signature(fn)
        kept = [p for n, p in sig.parameters.items() if n not in strats]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__                 # don't leak fn's signature
        return wrapper

    return deco
