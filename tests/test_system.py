"""End-to-end system test: the full production loop at toy scale —
data pipeline -> train steps -> checkpoint -> simulated failure ->
restore/resume -> prefill serving with the tiered KV runtime."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import InputShape
from repro.configs.registry import ARCHS
from repro.core import tiered_kv as tkv
from repro.data.pipeline import SyntheticLM
from repro.kernels import ref
from repro.launch import train as T
from repro.optim import adamw
from repro.runtime.fault_tolerance import RetryPolicy, run_supervised


def test_train_crash_resume_then_serve(tmp_path):
    arch = ARCHS["qwen3-1.7b"].reduced()
    shape = InputShape("e2e", seq_len=64, global_batch=4, kind="train")
    cfg = T.TrainConfig(remat="none", adamw=adamw.AdamWConfig(lr=1e-3),
                        warmup_steps=5, total_steps=20)
    data = SyntheticLM(arch, shape)
    ckpt = CheckpointManager(tmp_path, keep=2)
    step_fn = jax.jit(T.make_train_step(arch, cfg))

    state = {"crashed": False, "losses": []}

    def train_loop():
        if ckpt.latest_step() is not None:
            params0, opt0 = T.init_all(jax.random.key(0), arch, cfg)
            (params, opt_state), extra = ckpt.restore_with_fallback(
                (params0, opt0))
            start = extra["data_step"]
        else:
            params, opt_state = T.init_all(jax.random.key(0), arch, cfg)
            start = 0
        for step in range(start, 12):
            batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            state["losses"].append(float(metrics["loss"]))
            if step == 5:
                ckpt.save(step + 1, (params, opt_state),
                          extra={"data_step": step + 1})
                if not state["crashed"]:
                    state["crashed"] = True
                    raise RuntimeError("simulated node failure")
        return 12, params

    final_step, params = run_supervised(train_loop, ckpt,
                                        RetryPolicy(backoff_s=0.0))
    assert final_step == 12
    assert state["crashed"]
    assert ckpt.latest_step() == 6
    # training made progress despite the crash
    assert state["losses"][-1] < state["losses"][0]

    # ---- serve with the tiered KV runtime on the trained weights ----
    from repro.models import model_zoo, transformer
    pshape = InputShape("p", seq_len=64, global_batch=2, kind="prefill")
    batch = model_zoo.make_batch(arch, pshape)
    logits, cache = transformer.prefill(params, batch, arch, max_len=96)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    cfg_kv = tkv.TieredKVConfig(page=16, near_pages=2, interval=4)
    tiered = tkv.init_tiered_cache(cache["k"][0], cache["v"][0], cfg_kv)
    q = jax.random.normal(jax.random.key(1),
                          (2, arch.n_heads, arch.resolved_head_dim))
    pos = cache["pos"]
    for _ in range(3):
        tiered = tkv.plan_and_migrate(tiered, q, pos, cfg_kv)
    got = tkv.tiered_attention(tiered, q, pos, cfg_kv)
    want = ref.decode_attention_ref(
        q[:, None], tiered["far_k"], tiered["far_v"],
        jnp.full((2,), int(pos), jnp.int32))[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
