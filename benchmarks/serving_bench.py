"""Serving-engine scenario suite (the serving twin of the paper's Fig 8).

Six arrival scenarios x four tier policies through the continuous-batching
engine (`repro.serve`) — the matrix runs the engine the way it is meant to
be deployed since ISSUE 8: prefix cache ON, chunked admission prefill ON,
migration overlapped — reporting per cell:

  tokens/s (wall)       : aggregate decode throughput, post-compile.
  tokens/kcost          : modeled-byte-cost throughput (near pages streamed,
                          far pages gather-derated, IST billed — TierCosts).
  near-tier hit mass    : attention mass served by the near tier (the
                          paper's near-segment hit rate analogue).
  p50 / p99 latency     : modeled per-token latency (inter-token gaps;
                          first token includes queueing + prefill).

Plus two acceptance cells:

  continuous_vs_sequential : on steady Zipfian the engine must sustain
      >= 2x the aggregate tokens/s of single-sequence ``greedy_generate``
      serving, token-identical to that reference.  Since ISSUE 4 the
      baseline's TTFT is its modeled prefill cost (the engine's timebase),
      so the engine/sequential p50 TTFT columns are finally comparable.
  prefix_sharing : on the shared-system-prompt trace the radix prefix
      cache (``repro.serve.prefix``) must cut prefilled tokens >= 40% and
      improve modeled p50 TTFT vs the non-sharing engine, with emitted
      tokens bit-identical (ISSUE 3 acceptance).
  fused_kernel : dense vs fused read path on steady Zipfian (ISSUE 4
      acceptance): emitted tokens bit-identical, and the fused path's far
      rows touched == the sum of live non-promoted page rows (device walk
      accounting == independent host shadow), never ``n_pages*page*B``.
  pool_native : pool-as-single-source-of-truth memory (ISSUE 5
      acceptance): peak live KV bytes (referenced pool pages; near-tier
      copies are derived duplicates, reported separately) <= 0.6x the
      dense-equivalent per-slot master on the shared_system_prompt and
      long_context_summarize traces, with zero orphaned pages (the
      engine's shutdown refcount sweep runs inside every cell).
  mesh_scaling : ISSUE 10 acceptance — 4 data-parallel engine lanes
      (round-robin admissions, per-lane byte-cost clocks, fleet clock =
      slowest lane) must deliver >= 3x the single-lane modeled tokens/cost
      on the slot-bound steady Zipfian, tokens bit-identical; the
      per-device throughput column is regression-gated.
  chunked_prefill : ISSUE 8 acceptance — budgeted chunked admission
      prefill + overlapped migration vs the synchronous engine on the
      two stall-dominated traces (bursty, long_context_stragglers):
      emitted tokens bit-identical, and modeled p99 token latency AND
      p50 TTFT both improve >= 25% (the long-prompt admission stall no
      longer lands inside in-flight requests' inter-token gaps).

``run_all`` also emits **BENCH_serving.json** (tokens/s, p50/p99 latency,
TTFT, far-rows-touched, live-KV-bytes per cell) so the bench trajectory
has data points — `benchmarks/check_bench_regression.py` diffs a fresh run
against the committed file in CI.

  PYTHONPATH=src python -m benchmarks.serving_bench
"""

from __future__ import annotations

import json

import jax

from repro.configs.registry import ARCHS
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer
from repro.serve import (DataParallelEngine, ServingConfig, ServingEngine,
                         ServingReport, sequential_baseline)
from repro.serve.trace import SCENARIOS

POLICIES = ("SC", "WMC", "BBC", "STATIC")


def _setup(arch_name="qwen3-1.7b", seed=0):
    arch = ARCHS[arch_name].reduced()
    params = transformer.init_params(jax.random.key(seed), arch)
    return arch, params


def _config(policy: str, n_slots=6, max_len=128, page=16, near_pages=2,
            interval=4, share=False, fused=False, chunk=None,
            overlap=False) -> ServingConfig:
    tier = TieredKVConfig(page=page, near_pages=near_pages,
                          interval=interval, policy=policy,
                          fused_kernel=fused)
    return ServingConfig(n_slots=n_slots, max_len=max_len,
                         prefill_bucket=16, tier=tier, share_prefix=share,
                         prefill_chunk_tokens=chunk,
                         overlap_migration=overlap)


# The matrix's deployment config (ISSUE 8): radix prefix cache on (the
# shared-prefix scenarios must show non-zero hit rates — the old matrix ran
# share=False and pinned a 0.0 column for every cell), chunked admission
# prefill, migration on the background lane.
MATRIX_CHUNK = 96


def _matrix_config(policy: str, fused=False) -> ServingConfig:
    return _config(policy, share=True, fused=fused, chunk=MATRIX_CHUNK,
                   overlap=True)


def _traces(vocab: int):
    return {
        "steady_zipfian": SCENARIOS["steady_zipfian"](
            vocab, n_requests=12, prompt_len=24, max_new_tokens=16, gap=1),
        "bursty": SCENARIOS["bursty"](
            vocab, n_requests=12, prompt_len=24, max_new_tokens=16,
            burst=4, burst_gap=16),
        # gap=0 floods the queue (the post-ISSUE-8 trace fix): every
        # request arrives at once, so the median request waits behind the
        # stragglers' full-prompt prefills — the regime the chunked lane
        # exists for.  The old gap=2 let every arrival find a free slot
        # and its own prefill was the whole TTFT, hiding the admission
        # stall from the p50 columns entirely.
        "long_context_stragglers": SCENARIOS["long_context_stragglers"](
            vocab, n_requests=10, prompt_len=16, max_new_tokens=12,
            straggler_every=4, long_factor=4, gap=0),
        "shifting_hotspot": SCENARIOS["shifting_hotspot"](
            vocab, n_requests=12, prompt_len=24, max_new_tokens=16, gap=1),
        "shared_system_prompt": SCENARIOS["shared_system_prompt"](
            vocab, n_requests=12, sys_len=64, user_len=16,
            max_new_tokens=12, gap=2),
        "long_context_summarize": SCENARIOS["long_context_summarize"](
            vocab, n_requests=8, doc_len=96, question_len=16,
            max_new_tokens=16, gap=2),
    }


def bench_scenarios(arch_name="qwen3-1.7b", policies=POLICIES):
    """All scenarios x all policies.  One engine per policy (the jitted
    decode/plan programs are shared across its six scenario runs)."""
    arch, params = _setup(arch_name)
    traces = _traces(arch.vocab)
    rows = []
    for policy in policies:
        eng = ServingEngine(params, arch, _matrix_config(policy))
        for name, trace in traces.items():
            eng.run(trace, "warmup")    # compile this cell's shapes
                                        # (prefill buckets differ by
                                        # scenario) outside the timed run
            rep = eng.run(trace, name)
            rows.append(rep.summary_row())
    return rows


def bench_continuous_vs_sequential(arch_name="qwen3-1.7b", policy="BBC"):
    """Acceptance cell: >= 2x sequential greedy_generate on steady Zipfian,
    token-identical outputs."""
    arch, params = _setup(arch_name)
    cfg = _config(policy)
    trace = _traces(arch.vocab)["steady_zipfian"]
    eng = ServingEngine(params, arch, cfg)
    eng.run(trace, "warmup")
    rep = eng.run(trace, "steady_zipfian")
    sequential_baseline(params, arch, trace, cfg)       # warm the jits
    base = sequential_baseline(params, arch, trace, cfg,
                               "steady_zipfian")
    mismatches = sum(rep.outputs[r] != base.outputs[r] for r in rep.outputs)
    speedup = rep.tokens_per_s_wall / base.tokens_per_s_wall
    assert mismatches == 0, \
        f"{mismatches} sequences diverge from greedy_generate"
    assert speedup >= 2.0, \
        f"continuous batching only {speedup:.2f}x sequential"
    # Same-timebase TTFT (ISSUE 4 satellite re-pin): the baseline's TTFT is
    # its modeled prefill cost; the engine adds queueing on top, so its p50
    # may exceed the baseline's on oversubscribed traces — the column pair
    # is now meaningful, not a 0-vs-prefill artifact.
    assert base.p50_ttft > 0, "sequential TTFT must include prefill cost"
    return [
        ("continuous_vs_sequential", "engine_tok_s",
         round(rep.tokens_per_s_wall, 1)),
        ("continuous_vs_sequential", "sequential_tok_s",
         round(base.tokens_per_s_wall, 1)),
        ("continuous_vs_sequential", "speedup", round(speedup, 2)),
        ("continuous_vs_sequential", "outputs_identical", mismatches == 0),
        ("continuous_vs_sequential", "p50_ttft_engine",
         round(rep.p50_ttft, 1)),
        ("continuous_vs_sequential", "p50_ttft_sequential",
         round(base.p50_ttft, 1)),
    ]


def bench_fused_kernel(arch_name="qwen3-1.7b", policy="BBC"):
    """ISSUE 4 acceptance cell: the fused page-table-walking read path vs
    the dense (materializing) oracle on steady Zipfian — emitted tokens
    bit-identical; far rows touched == sum of live, non-promoted page rows
    (device walk accounting == independent host shadow), a fraction of the
    materializing path's ``n_pages * page * B``."""
    arch, params = _setup(arch_name)
    trace = _traces(arch.vocab)["steady_zipfian"]
    dense_eng = ServingEngine(params, arch, _config(policy))
    fused_eng = ServingEngine(params, arch, _config(policy, fused=True))
    dense_eng.run(trace, "warmup")
    dense = dense_eng.run(trace, "steady_zipfian")
    fused_eng.run(trace, "warmup")
    fused = fused_eng.run(trace, "steady_zipfian")
    assert dense.outputs == fused.outputs, \
        "fused kernel changed emitted tokens"
    assert fused.far_rows_touched == fused.far_rows_host, \
        "fused walk accounting diverges from the host shadow"
    assert fused.far_rows_touched < fused.far_rows_dense
    return [
        ("fused_kernel", "outputs_identical", dense.outputs == fused.outputs),
        ("fused_kernel", "far_rows_touched", fused.far_rows_touched),
        ("fused_kernel", "far_rows_host_shadow", fused.far_rows_host),
        ("fused_kernel", "far_rows_dense_equiv", fused.far_rows_dense),
        ("fused_kernel", "far_rows_saved_frac",
         round(fused.far_rows_saved_frac, 3)),
        ("fused_kernel", "fused_tok_s", round(fused.tokens_per_s_wall, 1)),
        ("fused_kernel", "dense_tok_s", round(dense.tokens_per_s_wall, 1)),
    ]


def bench_prefix_sharing(arch_name="qwen3-1.7b", policy="BBC"):
    """Acceptance cell: shared-system-prompt trace through the sharing and
    non-sharing engines — >= 40% fewer prefilled tokens, better modeled p50
    TTFT, bit-identical emitted tokens.  A multi-turn-chat cell reports the
    re-arrival hit rate alongside."""
    arch, params = _setup(arch_name)
    trace = SCENARIOS["shared_system_prompt"](
        arch.vocab, n_requests=10, sys_len=64, user_len=16,
        max_new_tokens=12, gap=2)
    base_eng = ServingEngine(params, arch, _config(policy))
    share_eng = ServingEngine(params, arch, _config(policy, share=True))
    base_eng.run(trace, "warmup")
    base = base_eng.run(trace, "shared_system_prompt")
    share_eng.run(trace, "warmup")
    share = share_eng.run(trace, "shared_system_prompt")
    assert base.outputs == share.outputs, \
        "prefix sharing changed emitted tokens"
    saved = share.prefill_saved_frac
    assert saved >= 0.4, f"only {saved:.0%} prefill tokens saved"
    assert share.p50_ttft < base.p50_ttft, \
        f"p50 TTFT regressed: {share.p50_ttft} vs {base.p50_ttft}"

    chat = SCENARIOS["multi_turn_chat"](arch.vocab, n_sessions=3, turns=3,
                                        base_len=32, turn_len=16,
                                        max_new_tokens=8, think_gap=24)
    chat_eng = ServingEngine(params, arch, _config(policy, share=True))
    chat_eng.run(chat, "warmup")
    chat_rep = chat_eng.run(chat, "multi_turn_chat")
    return [
        ("prefix_sharing", "prefill_tokens_saved_frac", round(saved, 3)),
        ("prefix_sharing", "prefix_hit_rate",
         round(share.prefix_hit_rate, 3)),
        ("prefix_sharing", "p50_ttft_base", round(base.p50_ttft, 1)),
        ("prefix_sharing", "p50_ttft_sharing", round(share.p50_ttft, 1)),
        ("prefix_sharing", "outputs_identical", base.outputs == share.outputs),
        ("prefix_sharing", "chat_prefix_hit_rate",
         round(chat_rep.prefix_hit_rate, 3)),
    ]


def bench_pool_native(arch_name="qwen3-1.7b", policy="BBC"):
    """ISSUE 5 acceptance cell: with the pool as the single source of truth
    (no dense per-slot KV master anywhere in the engine), peak live KV
    bytes — referenced pool pages + near-tier copies, all layers, K and V —
    must be <= 0.6x the dense-equivalent master's fixed footprint on the
    two sharing-heavy traces.  Zero orphaned pages is asserted by the
    engine's shutdown refcount sweep inside every run."""
    arch, params = _setup(arch_name)
    # shared-system-prompt: many tenants of one prompt prefix
    ssp = SCENARIOS["shared_system_prompt"](
        arch.vocab, n_requests=12, sys_len=64, user_len=16,
        max_new_tokens=16, gap=2)
    eng = ServingEngine(params, arch, _config(policy, share=True))
    eng.run(ssp, "warmup")
    rep = eng.run(ssp, "shared_system_prompt")
    # long-context summarize: few slots, one very long shared document
    lcs = SCENARIOS["long_context_summarize"](
        arch.vocab, n_requests=6, doc_len=192, question_len=16,
        max_new_tokens=8, gap=4)
    tier = TieredKVConfig(page=16, near_pages=2, interval=4, policy=policy)
    lcs_cfg = ServingConfig(n_slots=4, max_len=256, prefill_bucket=16,
                            tier=tier, share_prefix=True)
    lcs_eng = ServingEngine(params, arch, lcs_cfg)
    lcs_eng.run(lcs, "warmup")
    lcs_rep = lcs_eng.run(lcs, "long_context_summarize")
    for r in (rep, lcs_rep):
        assert r.kv_live_ratio <= 0.6, \
            f"{r.scenario}: live KV {r.kv_live_ratio:.3f}x dense (> 0.6)"
    return [
        ("pool_native", "ssp_kv_bytes_live", rep.kv_bytes_live),
        ("pool_native", "ssp_kv_bytes_dense_equiv",
         rep.kv_bytes_dense_equiv),
        ("pool_native", "ssp_kv_live_ratio", round(rep.kv_live_ratio, 3)),
        ("pool_native", "lcs_kv_bytes_live", lcs_rep.kv_bytes_live),
        ("pool_native", "lcs_kv_bytes_dense_equiv",
         lcs_rep.kv_bytes_dense_equiv),
        ("pool_native", "lcs_kv_live_ratio",
         round(lcs_rep.kv_live_ratio, 3)),
        ("pool_native", "lcs_prefill_saved_frac",
         round(lcs_rep.prefill_saved_frac, 3)),
    ]


def bench_chunked_prefill(arch_name="qwen3-1.7b", policy="BBC",
                          chunk=MATRIX_CHUNK):
    """ISSUE 8 acceptance cell: chunked admission prefill + overlapped
    migration vs the synchronous engine on the two stall-dominated traces.
    The overlap must not change a single emitted token (the chunk-resume
    step is bit-identical to one-shot prefill and the scheduler change is
    pure timing), while modeled p99 token latency and p50 TTFT both drop
    >= 25%: admission prefills no longer land whole inside in-flight
    requests' inter-token gaps, and queued requests stop serializing
    behind full-prompt prefills."""
    arch, params = _setup(arch_name)
    traces = _traces(arch.vocab)
    out = []
    for name in ("bursty", "long_context_stragglers"):
        trace = traces[name]
        sync_eng = ServingEngine(params, arch, _config(policy))
        over_eng = ServingEngine(params, arch,
                                 _config(policy, chunk=chunk, overlap=True))
        sync_eng.run(trace, "warmup")
        sync = sync_eng.run(trace, name)
        over_eng.run(trace, "warmup")
        over = over_eng.run(trace, name)
        assert sync.outputs == over.outputs, \
            f"{name}: chunked prefill changed emitted tokens"
        p99_gain = 1.0 - over.p99_lat / sync.p99_lat
        ttft_gain = 1.0 - over.p50_ttft / sync.p50_ttft
        assert p99_gain >= 0.25, \
            f"{name}: p99 latency only improved {p99_gain:.0%} " \
            f"({sync.p99_lat:.0f} -> {over.p99_lat:.0f})"
        assert ttft_gain >= 0.25, \
            f"{name}: p50 TTFT only improved {ttft_gain:.0%} " \
            f"({sync.p50_ttft:.0f} -> {over.p50_ttft:.0f})"
        out += [
            ("chunked_prefill", f"{name}_outputs_identical", True),
            ("chunked_prefill", f"{name}_p99_lat_sync",
             round(sync.p99_lat, 1)),
            ("chunked_prefill", f"{name}_p99_lat_chunked",
             round(over.p99_lat, 1)),
            ("chunked_prefill", f"{name}_p99_gain", round(p99_gain, 3)),
            ("chunked_prefill", f"{name}_p50_ttft_sync",
             round(sync.p50_ttft, 1)),
            ("chunked_prefill", f"{name}_p50_ttft_chunked",
             round(over.p50_ttft, 1)),
            ("chunked_prefill", f"{name}_ttft_gain", round(ttft_gain, 3)),
            ("chunked_prefill", f"{name}_prefill_chunks",
             over.prefill_chunks),
            ("chunked_prefill", f"{name}_migration_deferrals",
             over.migration_deferrals),
        ]
    return out


def bench_mesh_scaling(arch_name="qwen3-1.7b", policy="BBC", lanes=4):
    """ISSUE 10 acceptance cell: data-parallel serving over the mesh's
    'data' axis — R engine replicas, round-robin admissions by arrival,
    per-lane byte-cost clocks, fleet clock = slowest lane.  On the
    slot-bound steady Zipfian (4 slots, 48 uniform requests: each lane
    keeps its slots saturated long enough to amortize its admission
    ramp, and prefills stop serializing on a single clock) the modeled
    fleet throughput at 4 lanes must be >= 3x the single-lane engine, with emitted tokens
    bit-identical — decode tokens are batching-invariant, so
    partitioning the trace changes no token.  Lanes are host-modeled
    (every replica is the same jitted program with its own clock), so
    this cell runs on any device count; the kernel-level KV-head
    sharding is pinned by tests/test_mesh_serving.py on the mesh-4dev
    CI leg.  ``check_bench_regression`` gates every ``tok_per_kcost*``
    key in this cell, including the per-device column."""
    arch, params = _setup(arch_name)
    trace = SCENARIOS["steady_zipfian"](
        arch.vocab, n_requests=48, prompt_len=24, max_new_tokens=16, gap=1)
    cfg = _config(policy, n_slots=4)
    dp = DataParallelEngine(params, arch, cfg, n_replicas=lanes)
    dp.engine.run(trace, "warmup")          # one engine serves every lane:
                                            # compile once, reuse R+1 times
    single = dp.engine.run(trace, "steady_zipfian")
    fleet = dp.run(trace, "steady_zipfian")
    assert fleet.outputs == single.outputs, \
        "data-parallel lanes changed emitted tokens"
    assert fleet.tokens == single.tokens
    speedup = fleet.tokens_per_cost / single.tokens_per_cost
    assert speedup >= 3.0, \
        f"{lanes}-lane modeled throughput only {speedup:.2f}x single-lane"
    return [
        ("mesh_scaling", "lanes", lanes),
        ("mesh_scaling", "outputs_identical", True),
        ("mesh_scaling", "tok_per_kcost_1lane",
         round(single.tokens_per_cost * 1e3, 3)),
        ("mesh_scaling", "tok_per_kcost_fleet",
         round(fleet.tokens_per_cost * 1e3, 3)),
        ("mesh_scaling", "tok_per_kcost_per_device",
         round(fleet.tokens_per_cost / lanes * 1e3, 3)),
        ("mesh_scaling", "speedup_modeled", round(speedup, 2)),
    ]


def run_all(out_path: str | None = "BENCH_serving.json"):
    rows = [ServingReport.HEADER] + bench_scenarios()
    rows += bench_continuous_vs_sequential()
    rows += bench_prefix_sharing()
    rows += bench_fused_kernel()
    rows += bench_pool_native()
    rows += bench_chunked_prefill()
    rows += bench_mesh_scaling()
    for r in rows:
        print(",".join(str(x) for x in r))
    if out_path:
        header = ServingReport.HEADER
        matrix = [dict(zip(header, r)) for r in rows
                  if len(r) == len(header) and r != header]
        cells: dict = {}
        for r in rows:
            if len(r) == 3:
                cells.setdefault(r[0], {})[r[1]] = r[2]
        with open(out_path, "w") as f:
            json.dump({"matrix": matrix, "cells": cells}, f, indent=1)
        print(f"wrote {out_path}")
    return rows


if __name__ == "__main__":
    run_all()
