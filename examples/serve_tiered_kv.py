"""Serve a reduced model with the TL-DRAM tiered KV cache.

Prefill a batch of prompts, then decode while the BBC policy migrates hot KV
pages into the near tier; prints per-interval near-tier attention-mass
coverage and verifies the tiered path matches standard attention exactly.

  PYTHONPATH=src python examples/serve_tiered_kv.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.configs.registry import ARCHS
from repro.core import tiered_kv as tkv
from repro.kernels import ref
from repro.models import model_zoo, transformer


def main():
    arch = ARCHS["yi-9b"].reduced()
    S, B, steps = 256, 2, 48
    max_len = S + 64           # page-aligned cache (page=32)
    shape = InputShape("serve", seq_len=S, global_batch=B, kind="prefill")
    params = transformer.init_params(jax.random.key(0), arch)
    batch = model_zoo.make_batch(arch, shape)

    print(f"prefill {B}x{S} ({arch.name} reduced)...")
    logits, cache = transformer.prefill(params, batch, arch, max_len=max_len)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    # Wrap layer-0's KV in the tiered cache to demonstrate the read path
    # (the full per-layer integration is exercised in tests/benchmarks).
    cfg = tkv.TieredKVConfig(page=32, near_pages=4, interval=8)
    tiered = tkv.init_tiered_cache(cache["k"][0], cache["v"][0], cfg)

    decode = jax.jit(lambda p, c, b: transformer.decode_step(p, c, b, arch))
    H = arch.n_heads
    hd = arch.resolved_head_dim
    for step in range(steps):
        logits, cache = decode(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = cache["pos"]

        q = jax.random.normal(jax.random.key(step), (B, H, hd)) * 0.3
        tiered["far_k"] = cache["k"][0]
        tiered["far_v"] = cache["v"][0]
        if step % cfg.interval == 0:
            tiered = tkv.plan_and_migrate(tiered, q, pos, cfg)
            masses = tkv.page_masses(q, tiered, pos, cfg)
            cov = float((masses * (tiered["slot_of_page"] >= 0)).sum()
                        / max(float(masses.sum()), 1e-9))
            out_t = tkv.tiered_attention(tiered, q, pos, cfg)
            out_ref = ref.decode_attention_ref(
                q[:, None], tiered["far_k"], tiered["far_v"],
                jnp.full((B,), int(pos), jnp.int32))[:, 0]
            err = float(jnp.max(jnp.abs(out_t - out_ref)))
            print(f"step {step:3d} near-mass={cov:.3f} "
                  f"migrations={int(tiered['migrations'])} "
                  f"tiered-vs-exact max|err|={err:.2e}")
    print("generated tokens (seq 0):",
          np.asarray(tok)[0].tolist())


if __name__ == "__main__":
    main()
