"""Unified near/far tier subsystem (paper Sec. 4-5; docs/tier.md).

One policy engine for every substrate that has a small fast "near" segment
caching a large slow "far" segment:

  * `repro.tier.costs`      — `TierCosts`, the cost landscape (units are the
    substrate's: nanoseconds for DRAM, modeled byte-costs for TPU tiers).
  * `repro.tier.rules`      — the decision-rule core: eligibility, victim
    ordering and acceptance for all four paper policies (SC / WMC / BBC /
    STATIC), written against an array namespace so NumPy and JAX execute the
    same arithmetic.
  * `repro.tier.engine`     — per-access NumPy engine, batched over G
    independent tier groups (the DRAM simulator's bank x subarray grid).
  * `repro.tier.jax_engine` — jittable interval-mode engine for the TPU
    runtime (tiered KV cache, tiered embedding table).
  * `repro.tier.reference`  — the original object/dict policies, kept as the
    oracle for stream-replay parity tests.
"""

from repro.tier.costs import TierCosts
from repro.tier.engine import Decision, TierEngine
from repro.tier.rules import POLICY_NAMES, ema_update

__all__ = [
    "TierCosts", "TierEngine", "Decision", "POLICY_NAMES", "ema_update",
]
