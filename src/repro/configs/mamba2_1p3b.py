"""Mamba2-1.3B: attention-free SSD (state-space duality) decoder.

[arXiv:2405.21060; unverified] 48L d_model=2048, d_inner=2*d_model, 64 SSD
heads of dim 64, ssm_state=128, vocab=50280.  O(1) decode state: the TL-DRAM
KV-tier mechanism is inapplicable (no KV cache exists) — see docs/design.md
§Arch-applicability.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    ssm=SSMConfig(d_state=128, n_heads=64, head_dim=64),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
