"""Invariant passes over walked jaxprs / lowered HLO.

Each pass is a named rule over one ``AnalysisTarget``; the registry is what
``run_analysis`` iterates and what ``docs/design.md`` §3 catalogs.  Adding a
pass = write a ``run(target) -> list[Violation]`` function and ``register``
it — every registered target across the kernel-mode matrix gets it for
free.

Shipped passes:

  no-dense-far-view : no intermediate of a target-forbidden shape — the
      (B, n_pages, C) equality tensor anywhere, the batched far view
      (B, n_pages*page, Hkv, hd) wherever the mode promises a walk instead
      of a materialization.  Generalizes the PR-4/PR-5 jaxpr shape pin.
  f32-accumulation  : every attention-read-path dot (a dot with a raw-KV
      operand per the walker's taint lattice; ALL dots inside Pallas
      kernels) must accumulate in f32 — output dtype f32/f64 (operand
      dtypes or ``preferred_element_type``) or an immediate explicit cast.
      Catches the PR-4 bf16 greedy-tie bug class statically.
  no-host-sync      : no callback / infeed / outfeed primitives inside a
      per-tick jitted step — one host round-trip per token would dominate
      the decode clock.
  vmem-budget       : every intermediate priced with the
      ``launch.hlo_analysis`` dtype table must fit the 64 MiB budget
      ``kernels.paged_gather`` enforces dynamically at call time — here the
      same bound holds statically over ALL intermediates of the step.
  no-collectives    : migration planning (the IST analogue) must lower to
      pure on-device copies — its optimized HLO contains no collective ops
      (the pin from tests/test_tiered_runtime.py).

The pool-ownership AST linter lives in ``repro.analysis.ownership`` and is
run by the runner alongside these jaxpr passes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.analysis import walker
from repro.analysis.report import Violation
from repro.analysis.targets import AnalysisTarget

_F32 = ("float32", "float64")

# Host-sync primitives: anything that escapes the device inside a step.
_HOST_SYNC_PRIMS = ("callback", "infeed", "outfeed")


@dataclass
class InvariantPass:
    name: str
    doc: str
    run: Callable[[AnalysisTarget], list]
    applies: Callable[[AnalysisTarget], bool] = lambda t: True


PASSES: list[InvariantPass] = []


def register(name: str, doc: str, applies=lambda t: True):
    def deco(fn):
        PASSES.append(InvariantPass(name=name, doc=doc, run=fn,
                                    applies=applies))
        return fn
    return deco


@register("no-dense-far-view",
          "no forbidden-shape intermediate (dense equality tensors, "
          "materialized far views) anywhere in the jitted step",
          applies=lambda t: bool(t.forbidden_shapes))
def no_dense_far_view(target: AnalysisTarget) -> list[Violation]:
    viols = []
    banned = {fs.shape: fs for fs in target.forbidden_shapes}
    seen = {}
    for we in target.walk():
        for a in we.out_avals:
            shape = tuple(getattr(a, "shape", ()))
            fs = banned.get(shape)
            if fs is not None and shape not in seen:
                seen[shape] = we
                viols.append(Violation(
                    pass_name="no-dense-far-view", rule=fs.rule,
                    where=target.name,
                    detail=f"intermediate of shape {shape}: {fs.reason}",
                    source=we.source))
    return viols


def _dot_compliant(we: walker.WalkedEqn) -> bool:
    in_dts = [str(getattr(a, "dtype", "")) for a in we.in_avals]
    if not any(dt.startswith(("float", "bfloat")) for dt in in_dts):
        return True                       # integer/bool dot: not our rule
    out_dt = str(getattr(we.out_avals[0], "dtype", "")) \
        if we.out_avals else ""
    if out_dt in _F32:
        return True                       # f32 operands or preferred f32
    return we.cast_f32                    # explicit-cast accumulation idiom


@register("f32-accumulation",
          "attention-read-path dots (raw-KV operand, or any dot inside a "
          "Pallas kernel) accumulate in f32")
def f32_accumulation(target: AnalysisTarget) -> list[Violation]:
    viols = []
    seen = set()
    for we in target.walk():
        if we.prim != "dot_general":
            continue
        read_path = we.in_pallas or walker.TAINT_RAW in we.in_taints
        if not read_path or _dot_compliant(we):
            continue
        shapes = "x".join(str(tuple(getattr(a, "shape", ())))
                          for a in we.in_avals[:2])
        out_dt = str(getattr(we.out_avals[0], "dtype", "?"))
        detail = (f"read-path dot {shapes} accumulates in {out_dt} "
                  f"(want f32 via preferred_element_type or explicit cast)")
        key = (shapes, out_dt)
        if key in seen:
            continue
        seen.add(key)
        viols.append(Violation(
            pass_name="f32-accumulation", rule="low-prec-dot",
            where=target.name, detail=detail, source=we.source))
    return viols


@register("no-host-sync",
          "no callback/infeed/outfeed primitives inside a per-tick step",
          applies=lambda t: t.per_tick)
def no_host_sync(target: AnalysisTarget) -> list[Violation]:
    viols = []
    seen = set()
    for we in target.walk():
        if any(tok in we.prim for tok in _HOST_SYNC_PRIMS) \
                and we.prim not in seen:
            seen.add(we.prim)
            viols.append(Violation(
                pass_name="no-host-sync", rule="host-primitive",
                where=target.name,
                detail=f"host-sync primitive `{we.prim}` in a per-tick "
                       f"step", source=we.source))
    return viols


@register("vmem-budget",
          "every intermediate fits the paged_gather 64 MiB VMEM budget")
def vmem_budget(target: AnalysisTarget) -> list[Violation]:
    from repro.kernels.paged_gather import DEFAULT_VMEM_BUDGET
    from repro.launch.hlo_analysis import aval_bytes
    viols = []
    seen = set()
    for we in target.walk():
        for a in we.out_avals:
            if a is None or not hasattr(a, "shape"):
                continue
            nbytes = aval_bytes(a)
            if nbytes <= DEFAULT_VMEM_BUDGET:
                continue
            key = (tuple(a.shape), str(a.dtype))
            if key in seen:
                continue
            seen.add(key)
            viols.append(Violation(
                pass_name="vmem-budget", rule="oversized-intermediate",
                where=target.name,
                detail=f"intermediate {tuple(a.shape)} {a.dtype} is "
                       f"{nbytes} B > {DEFAULT_VMEM_BUDGET} B budget "
                       f"(prim {we.prim})",
                source=we.source))
    return viols


@register("no-collectives",
          "collective-free unless declared: jaxpr collectives may only run "
          "over the target's allowed mesh axes, and optimized HLO may only "
          "contain collective kinds those declared collectives account for",
          applies=lambda t: t.check_collectives)
def no_collectives(target: AnalysisTarget) -> list[Violation]:
    """Axis-aware no-collectives (docs/design.md §3).

    Two layers, because they see different things:

      * jaxpr: collectives still carry mesh AXIS NAMES (``psum`` over
        ``('model',)``), so a target may declare ``allowed_axes`` — the
        mesh-sharded read path's `shard_map` stats gathers over 'model'
        are by-design — and anything over an undeclared axis is flagged
        (``collective-axis``).
      * optimized HLO: axis names are erased into replica groups, but
        GSPMD may also have INSERTED collectives the jaxpr never wrote
        (the involuntary-resharding bug class this pass exists to catch).
        A collective KIND in HLO is excused only when an allowed jaxpr
        collective lowers to that kind; unexpected kinds still fail
        (``collective-op``) — so declaring 'model' for an all-gather does
        not quietly bless a GSPMD-introduced all-reduce.

    A target with no ``allowed_axes`` (migration planning — the IST
    analogue must be pure on-device copies) keeps the original contract:
    ANY collective, at either layer, fails."""
    viols = []
    allowed = set(target.allowed_axes)
    excused_kinds = set()
    for we, axes in walker.jaxpr_collectives(target.walk()):
        bad = sorted(a for a in axes if a not in allowed)
        if bad:
            viols.append(Violation(
                pass_name="no-collectives", rule="collective-axis",
                where=target.name,
                detail=f"jaxpr collective `{we.prim}` over undeclared mesh "
                       f"axes {bad} (declared: "
                       f"{sorted(allowed) if allowed else 'none'})",
                source=we.source))
        else:
            excused_kinds.add(walker.COLLECTIVE_PRIMS[we.prim])
    present = walker.hlo_ops_present(target.hlo_text(), walker.COLLECTIVE_OPS)
    viols.extend(Violation(
        pass_name="no-collectives", rule="collective-op",
        where=target.name,
        detail=f"collective `{op}` in optimized HLO not accounted for by "
               f"a declared jaxpr collective — either an undeclared "
               f"explicit collective or a GSPMD-inserted reshard")
        for op in present if op not in excused_kinds)
    return viols
