"""Pallas kernel: page-granular KV gather from the shared far pool.

The paged far tier (docs/design.md §2d) keeps one refcounted pool of KV
pages; each slot's far view is its page table resolved against the pool.
XLA lowers that resolution to a row gather — fine, but grain-agnostic.  This
kernel exploits the page structure: the unit of transfer is a whole
(page, Hkv*hd) panel, so each grid step issues ONE dynamic VMEM load per
page instead of per-row gathers — the TL-DRAM observation that the far
segment's cost is per-activation, not per-bit, applied to the gather path.

Grid: (B, n_pages).  VMEM per step: the full pool plus one output page
panel — so the kernel REFUSES pools larger than ``vmem_budget_bytes``
(default 64 MiB, ~4x a real core's VMEM to leave interpret-mode headroom)
with a clear ``ValueError`` instead of letting the compiler OOM or silently
spill.  The fused walk kernel (`kernels.paged_attention`) is the
production-shaped alternative: it keeps the pool in HBM/ANY and DMAs one
page panel per live, non-promoted page.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_VMEM_BUDGET = 64 * 2 ** 20     # bytes of VMEM the pool may pin


def _paged_gather_kernel(ids_ref, pool_ref, o_ref):
    pid = ids_ref[0, 0]
    panel = pool_ref[pl.ds(jnp.maximum(pid, 0), 1), :, :]        # (1,page,D)
    o_ref[0, :, :] = jnp.where(pid >= 0, panel[0], 0.0).astype(o_ref.dtype)


def paged_gather(pool: jax.Array, page_ids: jax.Array,
                 interpret: bool = False,
                 vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
                 mesh=None) -> jax.Array:
    """pool: (P, page, Hkv, hd); page_ids: (B, n_pages) int32 (< 0 => zeros).

    Returns (B, n_pages*page, Hkv, hd): each row b is the contiguous
    materialization of b's page table against the pool.

    Raises ValueError when the pool would pin more than
    ``vmem_budget_bytes`` of VMEM per grid step.

    With a ``mesh`` whose 'model' axis divides Hkv the pool is
    KV-HEAD-SHARDED: each device gathers its head slice (a 1/m-size pool
    shard also means the VMEM budget is priced per SHARD) and a tiled
    ``all_gather`` over 'model' re-assembles the replicated view — the
    gather is a pure byte move, so the result is exactly the
    single-device materialization."""
    from repro.sharding.specs import kv_shard_count
    if mesh is not None and kv_shard_count(mesh, pool.shape[-2]) > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P_

        def local_gather(pk, ids):
            out = paged_gather(pk, ids, interpret=interpret,
                               vmem_budget_bytes=vmem_budget_bytes)
            return jax.lax.all_gather(out, "model", axis=2, tiled=True)

        return shard_map(
            local_gather, mesh=mesh,
            in_specs=(P_(None, None, "model"), P_()),
            out_specs=P_(), check_rep=False)(pool, page_ids)
    P, page, Hkv, hd = pool.shape
    B, n_pages = page_ids.shape
    D = Hkv * hd
    pool_bytes = P * page * D * pool.dtype.itemsize
    if pool_bytes > vmem_budget_bytes:
        raise ValueError(
            f"paged_gather maps the whole pool into VMEM per grid step: "
            f"pool is {pool_bytes} bytes ({P} pages x {page} x {D} x "
            f"{pool.dtype.itemsize}B) > budget {vmem_budget_bytes}. "
            f"Use the fused walk kernel (kernels.paged_attention, "
            f"TieredKVConfig.fused_kernel) for pools this large, or raise "
            f"vmem_budget_bytes explicitly.")
    pool2 = pool.reshape(P, page, D)

    out = pl.pallas_call(
        functools.partial(_paged_gather_kernel),
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, j)),
            pl.BlockSpec((P, page, D), lambda b, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page, D), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_pages * page, D), pool.dtype),
        interpret=interpret,
    )(page_ids, pool2)
    return out.reshape(B, n_pages * page, Hkv, hd)
