"""CLI: ``python -m repro.analysis`` — run the invariant engine.

Emits the JSON report on stdout; exits non-zero when any violation is not
waived by the baseline file.  A human-readable summary goes to stderr so
piping the JSON stays clean.

    python -m repro.analysis                       # $REPRO_KERNEL_MODE
    python -m repro.analysis --mode fused
    python -m repro.analysis --baseline analysis_baseline.json --out rep.json
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path


def main(argv=None) -> int:
    from repro.analysis.runner import (DEFAULT_BASELINE, DEFAULT_SRC_ROOT,
                                       run_analysis)

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr/HLO invariant engine + pool-ownership linter")
    ap.add_argument("--mode", choices=("dense", "gather", "fused"),
                    default=None,
                    help="kernel mode (default: $REPRO_KERNEL_MODE or dense)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="waiver file (JSON: violation key -> reason)")
    ap.add_argument("--src-root", default=str(DEFAULT_SRC_ROOT),
                    help="tree the ownership linter audits")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here instead of stdout")
    ap.add_argument("--no-ownership", action="store_true",
                    help="skip the AST linter (jaxpr/HLO passes only)")
    args = ap.parse_args(argv)

    report = run_analysis(mode=args.mode, src_root=args.src_root,
                          baseline=args.baseline,
                          with_ownership=not args.no_ownership)

    text = report.to_json()
    if args.out:
        Path(args.out).write_text(text + "\n")
    else:
        print(text)

    active = report.active
    waived = sum(1 for v in report.violations if v.waived)
    print(f"[repro.analysis] mode={report.kernel_mode} "
          f"targets={len(report.targets_run)} passes={len(report.passes_run)} "
          f"violations={len(active)} waived={waived}", file=sys.stderr)
    for v in active:
        loc = f" ({v.source})" if v.source else ""
        print(f"  FAIL {v.pass_name}/{v.rule} @ {v.where}: "
              f"{v.detail}{loc}", file=sys.stderr)
    for k in report.unused_baseline:
        print(f"  STALE baseline entry never matched: {k}", file=sys.stderr)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
