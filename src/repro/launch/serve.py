"""Serving step factories: prefill and decode (standard or tiered KV).

``make_prefill_step`` / ``make_decode_step`` are the units the dry-run lowers
for the ``prefill_*`` / ``decode_*`` / ``long_*`` shapes.  The tiered decode
path threads the TL-DRAM near/far KV cache through every layer's attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer


def make_prefill_step(arch: ArchConfig, max_len: int):
    def prefill_step(params, batch):
        return transformer.prefill(params, batch, arch, max_len=max_len)
    return prefill_step


def make_decode_step(arch: ArchConfig):
    def decode_step(params, cache, batch):
        return transformer.decode_step(params, cache, batch, arch)
    return decode_step


def make_paged_tiered_decode_step(arch: ArchConfig, tier_cfg: TieredKVConfig):
    """Paged tiered decode step over the pool-as-single-source-of-truth
    cache (ISSUE 5).  With ``tier_cfg.fused_kernel`` every layer reads
    through the page-table-walking Pallas kernel over the per-layer shared
    page pool + per-layer global near buffer — no far-view materialization
    on the hot path; without it, each layer materializes its far view from
    the SAME pool and runs the PR-4 dense reduction (bit-identical logits
    to the retired dense-master path).  ``cache`` carries the pool/near
    leaves (see ``transformer.paged_decode_step``); ``meta`` is the
    per-step read metadata (`core.tiered_kv.paged_step_metadata`), computed
    ONCE per decode step by the serving engine and shared by every layer.
    Returns (logits, new_cache, aux) with the layer-0 scoring query in
    ``aux``.

    ``tier_cfg.mesh`` threads through to the step: pool/near buffers
    KV-HEAD-SHARDED over the 'model' axis, page tables and walk metadata
    replicated, emitted tokens bit-identical to single-device
    (docs/design.md §2h)."""
    fused = bool(tier_cfg.fused_kernel)
    mesh = tier_cfg.mesh

    def decode_step(params, cache, batch, meta):
        return transformer.paged_decode_step(params, cache, batch, arch,
                                             meta, want_aux=True,
                                             fused=fused, mesh=mesh)
    return decode_step


def _constrain_pools(pool_k, pool_v, mesh):
    """Pin the (L, P, page, Hkv, hd) pools to their KV-head sharding after
    a prefill scatter, so GSPMD does not drift the pool layout to
    replicated between steps.  The scatter itself indexes only the page
    dim — exact semantics under the sharding — and no-ops when the mesh is
    absent or Hkv does not divide the 'model' axis (the GQA/MQA
    replication fallback)."""
    from repro.sharding.specs import kv_shard_count
    if mesh is None or kv_shard_count(mesh, pool_k.shape[-2]) == 1:
        return pool_k, pool_v
    from jax.sharding import NamedSharding, PartitionSpec as P
    ns = NamedSharding(mesh, P(*([None] * (pool_k.ndim - 2)), "model"))
    return (jax.lax.with_sharding_constraint(pool_k, ns),
            jax.lax.with_sharding_constraint(pool_v, ns))


def _replicated(mesh, *arrays):
    """Constrain ``arrays`` to fully-replicated under ``mesh``.

    The bit-identity firewall for the prefill factories (docs/design.md
    §2h): the pool they scatter into is KV-head-sharded, and without a
    boundary GSPMD back-propagates that sharding into the prefill
    transformer — the ``wo``/``lm_head`` contractions become per-shard
    partial sums combined by an all-reduce, whose bf16 rounding differs
    from the single-device full-dim reduction enough to flip greedy
    argmax.  Constraining the cache rows (and prefix gathers) to P()
    right at the scatter/attention boundary keeps the whole prefill
    compute replicated — bitwise the single-device program — while the
    scatter itself reshards the exact rows into the pool layout."""
    if mesh is None:
        return arrays if len(arrays) > 1 else arrays[0]
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = tuple(jax.lax.with_sharding_constraint(
        a, NamedSharding(mesh, P())) for a in arrays)
    return out if len(out) > 1 else out[0]


def _scatter_prompt_pages(pool_k, pool_v, k_rows, v_rows, ids, page: int):
    """Scatter a prefilled sequence's K/V rows into full-layer pool pages.

    pool_k/pool_v: (L, P, page, Hkv, hd); k_rows/v_rows: (L, T, Hkv, hd);
    ids: (n_pages,) pool id per prompt page, -1 entries dropped (already
    written shared-prefix pages, and pages past the request's range)."""
    L, T, Hkv, hd = k_rows.shape
    n = ids.shape[0]
    P = pool_k.shape[1]
    safe = jnp.where(ids >= 0, ids, P)
    rk = k_rows.reshape(L, n, page, Hkv, hd)
    rv = v_rows.reshape(L, n, page, Hkv, hd)
    return (pool_k.at[:, safe].set(rk, mode="drop"),
            pool_v.at[:, safe].set(rv, mode="drop"))


def make_pool_prefill_step(arch: ArchConfig, max_len: int, page: int,
                           mesh=None):
    """Prefill straight into allocated pool pages (ISSUE 5): one jitted
    program runs ``transformer.prefill`` and scatters the resulting cache
    rows into the per-layer page pool — the dense rows exist only as a
    transient inside the step; the pool is the only store that survives.
    Returns (logits, pool_k, pool_v).

    With ``mesh`` set the pools are KV-head-sharded; the prefill compute
    itself stays fully replicated (``_replicated`` — the bit-identity
    firewall) and only the exact rows reshard at the scatter."""
    if mesh is not None:
        from repro.sharding.specs import kv_shard_count
        if kv_shard_count(mesh, arch.n_kv_heads) == 1:
            mesh = None
    def prefill_step(params, batch, pool_k, pool_v, ids):
        logits, pcache = transformer.prefill(params, batch, arch,
                                             max_len=max_len)
        k_rows, v_rows = pcache["k"][:, 0], pcache["v"][:, 0]
        if mesh is not None:
            k_rows, v_rows = _replicated(mesh, k_rows, v_rows)
        pool_k, pool_v = _scatter_prompt_pages(
            pool_k, pool_v, k_rows, v_rows, ids, page)
        return logits, *_constrain_pools(pool_k, pool_v, mesh)
    return prefill_step


def make_pool_suffix_prefill_step(arch: ArchConfig, max_len: int, page: int,
                                  mesh=None):
    """Prefix-chunked variant of ``make_pool_prefill_step`` for the
    prefix-sharing admission path: ``batch`` carries only the prompt
    *suffix* (with absolute positions); ``k_pre``/``v_pre`` are the shared
    prefix's K/V pages gathered from the pool ((L, B, T_pre, Hkv, hd)).
    The returned cache rows are bit-identical to a full prefill of
    prefix+suffix (the token-parity property), and land straight in the
    pool.  Mesh handling as in ``make_pool_prefill_step`` — the gathered
    prefix is replicated too, so the suffix attention stays single-device
    bitwise."""
    if mesh is not None:
        from repro.sharding.specs import kv_shard_count
        if kv_shard_count(mesh, arch.n_kv_heads) == 1:
            mesh = None
    def prefill_step(params, batch, k_pre, v_pre, pool_k, pool_v, ids):
        if mesh is not None:
            k_pre, v_pre = _replicated(mesh, k_pre, v_pre)
        logits, pcache = transformer.prefill(params, batch, arch,
                                             max_len=max_len,
                                             prefix_kv=(k_pre, v_pre))
        k_rows, v_rows = pcache["k"][:, 0], pcache["v"][:, 0]
        if mesh is not None:
            k_rows, v_rows = _replicated(mesh, k_rows, v_rows)
        pool_k, pool_v = _scatter_prompt_pages(
            pool_k, pool_v, k_rows, v_rows, ids, page)
        return logits, *_constrain_pools(pool_k, pool_v, mesh)
    return prefill_step


def make_pool_chunk_prefill_step(arch: ArchConfig, max_len: int, page: int,
                                 mesh=None):
    """Chunk-resumable admission prefill (ISSUE 8): one jitted program
    resumes a prompt's prefill from a saved ``(pos, kv-rows-written)``
    cursor ``t_pre`` — it gathers the ``ceil(t_pre/page)`` already-written
    pool pages, slices them to exactly ``t_pre`` rows (a cursor mid-page is
    fine: the boundary page's tail past the cursor is pad garbage from the
    previous chunk and is discarded here, then rewritten below), runs the
    suffix-prefill leg of ``transformer.prefill`` over the chunk with
    absolute positions, and scatters the covered pages back into the pool.
    Rewriting the boundary page is an identity for rows below the cursor
    (those cache rows ARE the gathered pool bytes), so valid-row coverage
    grows monotonically and the final cache rows are bit-identical to a
    one-shot prefill — the same pinned property the shared-prefix suffix
    path relies on.

    ``t_pre`` must be static (it sizes the prefix slice): jit with
    ``static_argnames=("t_pre",)``.  ``prefix_ids`` are the pool pages
    holding rows ``[0, t_pre)``; ``ids`` is the full ``(n_pages,)`` scatter
    vector with -1 outside the chunk's pages.  Returns
    (logits, pool_k, pool_v) — logits row ``n-1`` of an S-completing chunk
    seeds the first decode token.  Mesh handling as in
    ``make_pool_prefill_step``: the prefix pages gathered from the sharded
    pool replicate before the chunk's attention, the chunk compute stays
    single-device bitwise, and the exact rows reshard at the scatter."""
    if mesh is not None:
        from repro.sharding.specs import kv_shard_count
        if kv_shard_count(mesh, arch.n_kv_heads) == 1:
            mesh = None
    def chunk_step(params, batch, pool_k, pool_v, prefix_ids, ids,
                   t_pre: int):
        k = pool_k[:, prefix_ids]
        L, m, _, Hkv, hd = k.shape
        k_pre = k.reshape(L, 1, m * page, Hkv, hd)[:, :, :t_pre]
        v_pre = pool_v[:, prefix_ids].reshape(
            L, 1, m * page, Hkv, hd)[:, :, :t_pre]
        if mesh is not None:
            k_pre, v_pre = _replicated(mesh, k_pre, v_pre)
        logits, pcache = transformer.prefill(params, batch, arch,
                                             max_len=max_len,
                                             prefix_kv=(k_pre, v_pre))
        k_rows, v_rows = pcache["k"][:, 0], pcache["v"][:, 0]
        if mesh is not None:
            k_rows, v_rows = _replicated(mesh, k_rows, v_rows)
        pool_k, pool_v = _scatter_prompt_pages(
            pool_k, pool_v, k_rows, v_rows, ids, page)
        return logits, *_constrain_pools(pool_k, pool_v, mesh)
    return chunk_step


def make_sparse_tiered_decode_step(arch: ArchConfig, near_pages: int = 8,
                                   page: int = 128, window: int = 1024,
                                   tier_cfg: TieredKVConfig | None = None):
    """TL-DRAM sparse serving mode: each step attends the near tier — a
    *materialized* contiguous buffer of policy-selected hot pages — plus the
    recent window (a contiguous slice of the far cache), instead of the full
    far cache.  HBM reads drop from O(T) to O(near + window) per layer.

    The near buffer is maintained by the unified tier engine between steps
    via pure on-device page copies (``core.tiered_kv.plan_and_migrate`` with
    any ``repro.tier`` policy — SC/WMC/BBC/STATIC, the IST analogue); the
    decode step only *reads* it.  Pass ``tier_cfg`` to source the near-tier
    geometry and policy from one ``TieredKVConfig`` (the single config knob
    for policy sweeps); the explicit ``near_pages``/``page`` arguments remain
    for callers without a runtime config.  An earlier iteration gathered
    pages on the fly inside the step: with the time axis model-sharded,
    GSPMD turned the dynamic page gather into per-layer all-gathers of the
    whole cache (bytes 5.3x WORSE than baseline, docs/experiments.md §Perf
    cell C iter 1) — materializing the near tier is what makes the paper's
    design work on TPU too.

    Exactness holds for all attention mass inside (near U window); the
    benchmark measures the residual mass (bench_tiered_kv: coverage >0.95
    under Zipfian attention).  Valid for steady-state decode (pos >= window).
    """
    if tier_cfg is not None:
        near_pages, page = tier_cfg.near_pages, tier_cfg.page
    from repro.models.layers import apply_rope, decode_attention, rms_norm
    from repro.models.layers import gelu_mlp, swiglu
    from repro.models import moe as moe_lib
    from repro.sharding import ctx

    def decode_step(params, cache, batch):
        x = transformer._embed_inputs(params, batch, arch
                                      ).astype(jnp.bfloat16)
        x = ctx.constrain(x, ctx.BATCH, None, None)
        pos = cache["pos"]
        ragged = pos.ndim == 1          # per-slot positions (serving engine)
        B_all = x.shape[0]
        pos_b = pos if ragged else jnp.broadcast_to(pos, (B_all,))
        cparams = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if a.dtype == jnp.float32 and a.ndim > 1 else a,
            params["layers"])
        layer_cache = {k: v for k, v in cache.items() if k != "pos"}

        def body(h, scanned):
            p, cl = scanned
            h = ctx.constrain(h, ctx.BATCH, None, None)
            normed = rms_norm(h, p["attn_norm"])
            q = jnp.einsum("bsd,dhk->bshk", normed, p["attn"]["wq"])
            k = jnp.einsum("bsd,dhk->bshk", normed, p["attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", normed, p["attn"]["wv"])
            positions = pos_b[:, None]
            q = apply_rope(q, positions, arch.rope_theta)
            k = apply_rope(k, positions, arch.rope_theta)
            T = cl["k"].shape[1]
            if ragged:
                b_idx = jnp.arange(h.shape[0])
                kc = cl["k"].at[b_idx, pos_b].set(k[:, 0])
                vc = cl["v"].at[b_idx, pos_b].set(v[:, 0])
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(cl["k"], k, pos, 1)
                vc = jax.lax.dynamic_update_slice_in_dim(cl["v"], v, pos, 1)

            B, _, Hkv, hd = k.shape
            # near tier: contiguous policy-maintained buffer (read-only
            # here); occupied slots form a prefix (tests/test_read_path.py)
            # so per-sequence occupancy is the token count cl["near_len"].
            k_near = cl["near_k"]                     # (B, Tn, Hkv, hd)
            v_near = cl["near_v"]
            # recent window: an incrementally-written ring buffer.  (A
            # dynamic_slice of the big time-sharded cache would make GSPMD
            # all-gather the whole cache per layer — measured 26x worse,
            # docs/experiments.md §Perf cell C iter 2.)
            if ragged:
                slot = pos_b % window
                k_win = cl["win_k"].at[b_idx, slot].set(k[:, 0])
                v_win = cl["win_v"].at[b_idx, slot].set(v[:, 0])
            else:
                k_win = jax.lax.dynamic_update_slice_in_dim(
                    cl["win_k"], k, pos % window, 1)
                v_win = jax.lax.dynamic_update_slice_in_dim(
                    cl["win_v"], v, pos % window, 1)
            # Two partial attentions + exact LSE merge: concatenating the
            # two differently-sharded buffers made GSPMD replicate the
            # result per layer (+47 ms collective, docs/experiments.md
            # §Perf cell C iter 3); separate passes keep each buffer's
            # time sharding local.
            from repro.core.tiered_kv import _far_stats
            from repro.kernels import ref as kref
            qf = q[:, 0]
            # Empty near slots MUST be masked: an all-zero slot would
            # contribute score-0 logits to the softmax (a real corruption
            # whenever the near tier is not yet full — pinned by
            # tests/test_read_path.py::TestNearTierOccupancyMask).
            near_live = (jnp.arange(k_near.shape[1])[None, :]
                         < cl["near_len"][:, None])
            # Ring slots beyond what has been written are dead too (only
            # matters before steady state, pos < window).
            win_live = (jnp.arange(window)[None, :]
                        < jnp.minimum(pos_b + 1, window)[:, None])
            sn = _far_stats(qf, k_near, v_near, near_live)
            sw = _far_stats(qf, k_win, v_win, win_live)
            out = kref.merge_attention_stats([sn, sw])[:, None].astype(q.dtype)
            attn_out = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
            h = h + attn_out
            normed2 = rms_norm(h, p["mlp_norm"])
            if arch.family == "moe":
                mlp_out, _ = moe_lib.moe_block(p["moe"], normed2, arch.moe,
                                               group_size=h.shape[0],
                                               no_drop=True)
            elif arch.mlp_gated:
                mlp_out = swiglu(p["mlp"], normed2)
            else:
                mlp_out = gelu_mlp(p["mlp"], normed2)
            h = h + mlp_out
            return h, {**cl, "k": kc, "v": vc, "win_k": k_win,
                       "win_v": v_win}

        x, new_cache = jax.lax.scan(body, x, (cparams, layer_cache))
        x = rms_norm(x, params["final_norm"].astype(jnp.bfloat16))
        logits = transformer._lm_logits(params, x, arch)
        logits = ctx.constrain(logits, ctx.BATCH, None, ctx.MODEL)
        return logits, {**new_cache, "pos": pos + 1}

    return decode_step


def sparse_cache_extras(arch: ArchConfig, batch: int, seq_len: int,
                        near_pages: int = 8, page: int = 128,
                        dtype=jnp.bfloat16,
                        tier_cfg: TieredKVConfig | None = None,
                        window: int = 1024):
    """Extra cache leaves for the sparse tiered decode step: the
    materialized near-tier buffers (maintained between steps by the
    ``repro.tier`` policy configured in ``tier_cfg``) plus ``near_len``,
    the per-sequence count of live near-tier tokens (occupied slots form a
    prefix, so one count per sequence fully describes occupancy)."""
    if tier_cfg is not None:
        near_pages, page = tier_cfg.near_pages, tier_cfg.page
    L = arch.n_layers
    hd = arch.resolved_head_dim
    tn = near_pages * page
    return {
        "near_k": jnp.zeros((L, batch, tn, arch.n_kv_heads, hd), dtype),
        "near_v": jnp.zeros((L, batch, tn, arch.n_kv_heads, hd), dtype),
        "near_len": jnp.zeros((L, batch), jnp.int32),
        "win_k": jnp.zeros((L, batch, window, arch.n_kv_heads, hd), dtype),
        "win_v": jnp.zeros((L, batch, window, arch.n_kv_heads, hd), dtype),
    }


def greedy_generate(params, arch: ArchConfig, prompt_batch: dict,
                    steps: int, max_len: int, step_fn=None,
                    prefill_fn=None):
    """Simple batched greedy generation driver (examples/tests).

    ``step_fn`` / ``prefill_fn``: optionally pass pre-jitted step functions
    so repeated calls (e.g. the serving benchmark's sequential baseline)
    don't recompile or dispatch eagerly — the computation is identical."""
    if prefill_fn is None:
        prefill_fn = lambda p, b: transformer.prefill(p, b, arch,
                                                      max_len=max_len)
    logits, cache = prefill_fn(params, prompt_batch)
    if arch.family == "audio":
        raise NotImplementedError("audio generation uses frame embeddings")
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    step = step_fn if step_fn is not None else jax.jit(make_decode_step(arch))
    for _ in range(steps - 1):
        logits, cache = step(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)[:, :, 0] \
            if logits.ndim == 4 else jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1), cache
