"""Read-path invariant fuzz suite for the paged far tier (ISSUE 3).

Randomized admit/decode/migrate/retire interleavings over the refcounted
page pool + radix prefix cache, asserting after EVERY step:

  (a) paged ``tiered_attention`` == monolithic dense attention over each
      active slot's live prefix (the TL-DRAM read-path correctness
      property, now through the page-table indirection and the *global*
      near tier),
  (b) every pool page's refcount == the number of slots referencing it,
      with zero leaks once all sequences retire,
  (c) the occupied-near-slots-prefix invariant (and mapping bijection)
      holds for the global near mapping — including through the
      release-path compaction that demotion of freed pages triggers,
  (d) pool-as-truth (ISSUE 5): gathering pool pages through the page table
      reproduces an INDEPENDENTLY-maintained dense oracle's rows exactly —
      the single-source-of-truth property ownership inversion rests on
      (there is no refresh pass to paper over a missed pool write).

The harness drives the real API (``paged_append_token``,
``paged_plan_and_migrate``, ``paged_release_pages``, ``PagePool``,
``RadixPrefixCache``) with synthetic K/V that depends only on (position,
token) — the property real transformer K/V has over shared prefixes — so a
sharing bug shows up as an attention mismatch, not a silent alias.

Driven by the seeded property harness (tests/_prop.py), so it runs without
hypothesis.

Kernel modes (ISSUE 4): the harness reads ``REPRO_KERNEL_MODE``
(dense | gather | fused, the CI matrix legs) to route every read through
the XLA far view, the Pallas paged-gather far view, or the fused
page-table-walking kernel; the fused-mode classes below additionally pin
fused == dense == monolithic on every interleaving step regardless of the
environment.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:
    from _prop import given, settings, strategies as st

from repro.core import tiered_kv as tkv
from repro.core.tiered_kv import PagePool, TieredKVConfig
from repro.kernels import ref
from repro.serve.prefix import RadixPrefixCache

PAGE = 8
N_PAGES = 5                  # per-slot page-table length (max_len = 40)
MAX_LEN = PAGE * N_PAGES
B = 3                        # slots
POOL = 22                    # pool pages: B*N_PAGES + retention slack
VOCAB = 40
HKV, HD = 2, 8

# CI kernel-mode matrix leg: route every fuzz read through this path
KERNEL_MODE = os.environ.get("REPRO_KERNEL_MODE", "dense")

_READS: dict = {}


def _read_fn(mode: str):
    """Jitted paged read for one kernel mode (compiled once per mode —
    the fuzz shapes are module constants)."""
    if mode not in _READS:
        cfg = TieredKVConfig(page=PAGE, near_pages=3, interval=2,
                             max_promotions=2,
                             gather_kernel=(mode == "gather"),
                             fused_kernel=(mode == "fused"))
        _READS[mode] = jax.jit(
            lambda cache, q, pos: tkv.paged_tiered_attention(cache, q, pos,
                                                             cfg))
    return _READS[mode]


def _kv(pos: int, tok: int) -> np.ndarray:
    """Deterministic per-(position, token) K/V rows — identical wherever the
    same token sits at the same position, like real prefix K/V."""
    rng = np.random.default_rng(1_000_003 * (pos + 1) + tok)
    return rng.normal(size=(2, HKV, HD)).astype(np.float32)


def _assert_global_mapping_invariants(sop, ros):
    """(c): occupied near slots form a prefix; mapping is a bijection."""
    sop, ros = np.asarray(sop), np.asarray(ros)
    occ = ros >= 0
    n_occ = int(occ.sum())
    assert occ[:n_occ].all(), f"occupied near slots not a prefix: {ros}"
    live = ros[occ]
    assert len(set(live.tolist())) == n_occ, f"duplicate pages: {ros}"
    for c, p in enumerate(ros):
        if p >= 0:
            assert sop[p] == c, (sop, ros)
    for p in range(sop.shape[0]):
        if sop[p] >= 0:
            assert ros[sop[p]] == p, (sop, ros)


class PagedWorld:
    """Scheduler-shaped driver over the paged tier model (no transformer)."""

    def __init__(self, seed: int, policy: str, share: bool,
                 kernel_mode: str | None = None):
        self.rng = np.random.default_rng(seed)
        self.kernel_mode = KERNEL_MODE if kernel_mode is None else kernel_mode
        self.cfg = TieredKVConfig(page=PAGE, near_pages=3, interval=2,
                                  max_promotions=2, policy=policy,
                                  gather_kernel=(self.kernel_mode == "gather"),
                                  fused_kernel=(self.kernel_mode == "fused"))
        self.cache = tkv.init_paged_cache(self.cfg, B, N_PAGES, POOL,
                                          HKV, HD, dtype=jnp.float32)
        self.pool = PagePool(POOL)
        self.prefix = RadixPrefixCache(self.pool, PAGE) if share else None
        self.pt = -np.ones((B, N_PAGES), np.int64)
        self.pos = np.zeros(B, np.int64)
        self.active = np.zeros(B, bool)
        self.tokens = np.zeros((B, MAX_LEN), np.int64)
        # pool-as-truth oracle (ISSUE 5): dense K/V rows maintained
        # INDEPENDENTLY of the pool (straight from _kv at every admit /
        # decode) — after every op, gathering pool pages through the page
        # table must reproduce these rows EXACTLY
        self.oracle_k = np.zeros((B, MAX_LEN, HKV, HD), np.float32)
        self.oracle_v = np.zeros((B, MAX_LEN, HKV, HD), np.float32)
        # shared prompt families: admissions draw a family prefix + a
        # random tail, so the trie sees real hits and real misses
        self.families = [self.rng.integers(0, VOCAB, MAX_LEN)
                        for _ in range(2)]
        self.q = jnp.asarray(self.rng.normal(size=(B, HKV * 2, HD)),
                             jnp.float32)
        self.total_hit_pages = 0
        # chunked admissions in flight (ISSUE 8): slot -> job.  A pending
        # slot holds refcounted pool pages and a resume cursor but NO page
        # table row — the read/append path must treat it exactly like a
        # free slot until the job completes.
        self.pending = {}

    # -- content plumbing ----------------------------------------------------

    def _write_page_from_tokens(self, pid: int, j: int, toks, upto: int):
        """Write positions [j*PAGE, upto) of a freshly-allocated page."""
        kp = np.zeros((PAGE, HKV, HD), np.float32)
        vp = np.zeros((PAGE, HKV, HD), np.float32)
        for pos in range(j * PAGE, upto):
            kv = _kv(pos, int(toks[pos]))
            kp[pos % PAGE], vp[pos % PAGE] = kv[0], kv[1]
        self.cache["pool_k"] = self.cache["pool_k"].at[pid].set(kp)
        self.cache["pool_v"] = self.cache["pool_v"].at[pid].set(vp)

    def dense_view(self):
        """Monolithic (B, MAX_LEN) K/V oracle from page table + pool."""
        pool_k = np.asarray(self.cache["pool_k"])
        pool_v = np.asarray(self.cache["pool_v"])
        k = np.zeros((B, MAX_LEN, HKV, HD), np.float32)
        v = np.zeros_like(k)
        for b in range(B):
            for j in range(N_PAGES):
                if self.pt[b, j] >= 0:
                    k[b, j * PAGE:(j + 1) * PAGE] = pool_k[self.pt[b, j]]
                    v[b, j * PAGE:(j + 1) * PAGE] = pool_v[self.pt[b, j]]
        return jnp.asarray(k), jnp.asarray(v)

    # -- ops ------------------------------------------------------------------

    def admit(self):
        free = np.flatnonzero(~self.active)
        if not free.size:
            return
        b = int(free[0])
        fam = self.families[self.rng.integers(len(self.families))]
        S = int(self.rng.integers(PAGE + 1, MAX_LEN - PAGE))
        tail = int(self.rng.integers(1, PAGE))
        toks = fam[:S].copy()
        toks[S - tail:] = self.rng.integers(0, VOCAB, tail)
        matched = []
        if self.prefix is not None:
            matched = self.prefix.match(toks)
            self.pool.acquire(matched)
            fresh, evicted = self.prefix.allocate(N_PAGES - len(matched))
            if evicted:
                self.cache = tkv.paged_release_pages(self.cache, evicted,
                                                     self.cfg)
        else:
            fresh = self.pool.allocate(N_PAGES)
        self.total_hit_pages += len(matched)
        row = matched + fresh
        self.pt[b] = row
        self.cache["page_table"] = self.cache["page_table"].at[b].set(
            jnp.asarray(row, jnp.int32))
        m = len(matched)
        for j in range(m, N_PAGES):            # prefill the unmatched pages
            upto = min(S, (j + 1) * PAGE)
            if upto > j * PAGE:
                self._write_page_from_tokens(row[j], j, toks, upto)
        if self.prefix is not None:
            self.prefix.insert(toks[:(S // PAGE) * PAGE],
                               row[:S // PAGE])
        self.tokens[b, :S] = toks
        for p in range(S):                     # oracle rows: matched pages
            kv = _kv(p, int(toks[p]))          # included (same (pos, token)
            self.oracle_k[b, p] = kv[0]        # => same bytes as the pool's
            self.oracle_v[b, p] = kv[1]        # first-tenant copy)
        self.pos[b] = S
        self.active[b] = True

    def decode(self):
        if not self.active.any():
            return
        can = self.active & (self.pos < MAX_LEN)
        if not can.any():
            return
        new_toks = self.rng.integers(0, VOCAB, B)
        kn = np.zeros((B, 1, HKV, HD), np.float32)
        vn = np.zeros_like(kn)
        for b in range(B):
            if can[b]:
                kv = _kv(int(self.pos[b]), int(new_toks[b]))
                kn[b, 0], vn[b, 0] = kv[0], kv[1]
        pos = jnp.asarray(np.where(can, self.pos, 0), jnp.int32)
        # inactive rows route through page_table -1 -> dropped writes
        cache = tkv.paged_append_token(self.cache, jnp.asarray(kn),
                                       jnp.asarray(vn), pos, self.cfg)
        self.cache = cache
        for b in range(B):
            if can[b]:
                self.tokens[b, self.pos[b]] = new_toks[b]
                self.oracle_k[b, self.pos[b]] = kn[b, 0]
                self.oracle_v[b, self.pos[b]] = vn[b, 0]
                self.pos[b] += 1

    def migrate(self):
        idle = bool(self.rng.integers(0, 2))
        self.cache = tkv.paged_plan_and_migrate(
            self.cache, self.q, jnp.asarray(self.pos, jnp.int32),
            self.cfg, idle=idle)

    def admit_partial(self):
        """Engine `_admit_chunked`: map the pages (trie match + acquire +
        allocate) but write nothing past the matched prefix — the prompt
        fills in over later ``advance_prefill`` chunks, interleaved with
        decode ticks on OTHER slots."""
        free = [b for b in np.flatnonzero(~self.active)
                if b not in self.pending]
        if not free:
            return
        b = int(free[0])
        fam = self.families[self.rng.integers(len(self.families))]
        S = int(self.rng.integers(PAGE + 1, MAX_LEN - PAGE))
        tail = int(self.rng.integers(1, PAGE))
        toks = fam[:S].copy()
        toks[S - tail:] = self.rng.integers(0, VOCAB, tail)
        matched = []
        if self.prefix is not None:
            matched = self.prefix.match(toks)
            self.pool.acquire(matched)
            fresh, evicted = self.prefix.allocate(N_PAGES - len(matched))
            if evicted:
                self.cache = tkv.paged_release_pages(self.cache, evicted,
                                                     self.cfg)
        else:
            fresh = self.pool.allocate(N_PAGES)
        self.total_hit_pages += len(matched)
        self.pending[b] = {"toks": toks, "S": S, "row": matched + fresh,
                           "cursor": len(matched) * PAGE}

    def advance_prefill(self):
        """One chunk of the FIFO-first pending job: write a random number
        of rows from the cursor (mid-page cursors rewrite the boundary
        page whole — an identity below the cursor), trie-insert completed
        pages, and on reaching S install the page table + activate —
        the engine's `_advance_prefills` + `_complete_prefill`."""
        if not self.pending:
            return
        b = next(iter(self.pending))
        job = self.pending[b]
        toks, S, row = job["toks"], job["S"], job["row"]
        c0 = job["cursor"]
        take = min(S - c0, int(self.rng.integers(1, 2 * PAGE + 1)))
        cur = c0 + take
        for j in range(c0 // PAGE, min(-(-cur // PAGE), N_PAGES)):
            upto = min(cur, (j + 1) * PAGE)
            if upto > j * PAGE:
                self._write_page_from_tokens(row[j], j, toks, upto)
        job["cursor"] = cur
        if self.prefix is not None and cur // PAGE > c0 // PAGE:
            self.prefix.insert(toks[:(cur // PAGE) * PAGE],
                               row[:cur // PAGE])
        if cur >= S:
            del self.pending[b]
            self.pt[b] = row
            self.cache["page_table"] = self.cache["page_table"].at[b].set(
                jnp.asarray(row, jnp.int32))
            self.tokens[b, :S] = toks
            for p in range(S):
                kv = _kv(p, int(toks[p]))
                self.oracle_k[b, p] = kv[0]
                self.oracle_v[b, p] = kv[1]
            self.pos[b] = S
            self.active[b] = True

    def retire(self):
        act = np.flatnonzero(self.active)
        if not act.size:
            return
        b = int(self.rng.choice(act))
        freed = self.pool.release([int(p) for p in self.pt[b] if p >= 0])
        if freed:
            self.cache = tkv.paged_release_pages(self.cache, freed, self.cfg)
        self.pt[b] = -1
        self.cache["page_table"] = self.cache["page_table"].at[b].set(-1)
        self.pos[b] = 0
        self.active[b] = False

    # -- invariants ------------------------------------------------------------

    def check(self):
        # (b) refcounts == number of referencing slots, exactly
        want = np.zeros(POOL, np.int64)
        for b in range(B):
            for p in self.pt[b]:
                if p >= 0:
                    want[p] += 1
        for job in self.pending.values():       # chunked admissions hold
            for p in job["row"]:                # their pages from mapping
                want[p] += 1                    # time, page table or not
        np.testing.assert_array_equal(self.pool.refcount, want)
        # pages on the free list are unreferenced and uncached
        for p in self.pool._free:
            assert self.pool.refcount[p] == 0 and not self.pool.cached[p]
        # (c) global near mapping invariants
        _assert_global_mapping_invariants(self.cache["slot_of_page"],
                                          self.cache["page_of_slot"])
        # near copies mirror the pool master for every occupied near slot
        ros = np.asarray(self.cache["page_of_slot"])
        near_k = np.asarray(self.cache["near_k"])
        pool_k = np.asarray(self.cache["pool_k"])
        for c, p in enumerate(ros):
            if p >= 0:
                np.testing.assert_array_equal(
                    near_k[c * PAGE:(c + 1) * PAGE], pool_k[p])
        # (a) paged two-tier read == monolithic dense attention, through
        # the configured kernel mode; in fused mode ALSO pin fused == dense
        # (the oracle) on the same state — promoted, unmapped and
        # partial-last-page entries all flow through the walk metadata
        if self.active.any():
            pos = jnp.asarray(self.pos, jnp.int32)
            got = _read_fn(self.kernel_mode)(self.cache, self.q, pos)
            k, v = self.dense_view()
            # pool-as-truth (ISSUE 5): the pool gathered through the page
            # table IS the oracle's dense rows, bit for bit, after every
            # admit/decode/migrate/retire step — the invariant ownership
            # inversion rests on (no refresh pass exists to paper over a
            # missed write)
            k_np, v_np = np.asarray(k), np.asarray(v)
            for b in range(B):
                n = int(self.pos[b])
                if self.active[b] and n > 0:
                    np.testing.assert_array_equal(
                        k_np[b, :n], self.oracle_k[b, :n])
                    np.testing.assert_array_equal(
                        v_np[b, :n], self.oracle_v[b, :n])
            want_out = ref.decode_attention_ref(self.q[:, None], k, v,
                                                pos)[:, 0]
            np.testing.assert_allclose(
                np.asarray(got)[self.active], np.asarray(want_out)[self.active],
                rtol=1e-5, atol=1e-5)
            if self.kernel_mode == "fused":
                dense = _read_fn("dense")(self.cache, self.q, pos)
                np.testing.assert_allclose(
                    np.asarray(got)[self.active],
                    np.asarray(dense)[self.active], rtol=1e-5, atol=1e-5)

    def drain(self):
        while self.pending:                 # finish in-flight chunked
            self.advance_prefill()          # admissions first: their pages
            self.check()                    # are live refcounts too
        while self.active.any():
            self.retire()
            self.check()
        assert (self.pool.refcount == 0).all(), "refcount leak after drain"


OPS = ("admit", "decode", "decode", "migrate", "retire")


class TestPagedInterleavings:
    @given(seed=st.integers(0, 999), policy=st.sampled_from(["SC", "WMC",
                                                             "BBC"]),
           share=st.booleans())
    @settings(max_examples=6, deadline=None)
    def test_random_interleaving_keeps_all_invariants(self, seed, policy,
                                                      share):
        world = PagedWorld(seed, policy, share)
        for _ in range(28):
            op = world.rng.choice(OPS, p=[0.3, 0.2, 0.2, 0.2, 0.1])
            getattr(world, op)()
            world.check()
        world.drain()

    def test_sharing_run_actually_shares_and_frees_cleanly(self):
        """A deterministic sharing-heavy run must register prefix hits,
        keep refcounts > 1 on shared pages at some point, and drain to
        zero refcounts with the prefix cache retaining pages."""
        world = PagedWorld(7, "BBC", share=True)
        world.families = world.families[:1]     # one family: every admit
                                                # after the first can share
        saw_shared = False
        schedule = ("admit", "admit", "admit", "decode", "migrate",
                    "decode", "migrate", "retire") * 5
        for op in schedule:
            getattr(world, op)()
            world.check()
            saw_shared |= bool((world.pool.refcount > 1).any())
        world.drain()
        assert world.total_hit_pages > 0, "trie never matched"
        assert saw_shared, "no page was ever shared by two slots"
        assert world.pool.cached.any(), "prefix cache retained nothing"


CHUNK_OPS = ("admit_partial", "advance_prefill", "advance_prefill",
             "decode", "migrate", "retire")


class TestChunkedPrefillInterleavings:
    """ISSUE 8 satellite: 'partial prefill then decode tick' op mix.

    A pending chunked admission owns refcounted pool pages with NO page
    table row; every check() proves decode appends, migrations, reads,
    sharing and retires stay correct while jobs are mid-chunk — including
    other slots trie-matching a still-chunking prompt's completed pages."""

    @given(seed=st.integers(0, 999),
           policy=st.sampled_from(["SC", "WMC", "BBC"]),
           share=st.booleans())
    @settings(max_examples=6, deadline=None)
    def test_partial_prefill_interleaved_with_decode(self, seed, policy,
                                                     share):
        world = PagedWorld(seed, policy, share)
        for _ in range(28):
            op = world.rng.choice(CHUNK_OPS,
                                  p=[0.25, 0.2, 0.2, 0.15, 0.1, 0.1])
            getattr(world, op)()
            world.check()
        world.drain()

    def test_pending_slot_is_invisible_until_completion(self):
        """The deterministic core of the overlap: admit slot 0 fully,
        admit slot 1 partially, then decode — slot 0 advances, slot 1's
        pages stay out of the read path and its refcounts stay pinned;
        completion flips it live with bit-exact rows."""
        world = PagedWorld(11, "BBC", share=True)
        world.families = world.families[:1]
        world.admit()
        world.admit_partial()
        assert world.pending and not world.active[1]
        held = list(world.pending[1]["row"])
        for _ in range(3):
            world.decode()
            world.check()
            assert not world.active[1]
            assert all(world.pool.refcount[p] >= 1 for p in held)
        while world.pending:
            world.advance_prefill()
            world.check()
        assert world.active[1]
        world.drain()


class TestFusedKernelInterleavings:
    """ISSUE 4 satellite: the fuzz interleavings in FUSED-kernel mode.

    Every check() in fused mode asserts fused == dense == monolithic over
    states that include promoted pages, unmapped page-table entries and
    partial last pages (the random interleavings produce all three)."""

    @given(seed=st.integers(0, 999),
           policy=st.sampled_from(["SC", "WMC", "BBC"]),
           share=st.booleans())
    @settings(max_examples=4, deadline=None)
    def test_random_interleaving_fused_equals_dense_and_monolithic(
            self, seed, policy, share):
        world = PagedWorld(seed, policy, share, kernel_mode="fused")
        for _ in range(22):
            op = world.rng.choice(OPS, p=[0.3, 0.2, 0.2, 0.2, 0.1])
            getattr(world, op)()
            world.check()
        world.drain()

    def test_fused_parity_at_page_boundaries(self):
        """pos % page == 0 is the sharp edge of the partial-last-page mask:
        the frontier page flips from 'one live row' to 'complete' to 'next
        page, one live row'.  Decode one token at a time across two page
        boundaries, checking fused == dense == monolithic at every step."""
        world = PagedWorld(5, "SC", share=False, kernel_mode="fused")
        world.admit()
        world.migrate()          # promote something so the near pass is live
        world.check()
        boundaries = 0
        while world.pos[world.active].max() < MAX_LEN - 1 and boundaries < 2:
            world.decode()
            if int(world.pos[world.active].max()) % PAGE == 0:
                boundaries += 1
                world.migrate()  # replan exactly at the boundary
            world.check()
        assert boundaries == 2, "never crossed two page boundaries"

    def test_fused_walk_skips_promoted_and_unmapped_pages(self):
        """The walk metadata must exclude promoted and unmapped pages —
        the far bytes the fused path touches are live non-promoted rows
        ONLY (the accounting the serving bench pins end-to-end)."""
        world = PagedWorld(9, "SC", share=False, kernel_mode="fused")
        world.admit()
        for _ in range(3):
            world.decode()
            world.migrate()
        world.check()
        cfg = world.cfg
        meta = tkv.paged_step_metadata(
            world.cache, jnp.asarray(world.pos, jnp.int32), cfg)
        sop = np.asarray(world.cache["slot_of_page"])
        promoted_pages = {int(p) for p in np.flatnonzero(sop >= 0)}
        assert promoted_pages, "no page promoted; test needs a near tenant"
        for b in range(B):
            walked = set(np.asarray(meta["walk_pid"])[b,
                         :int(meta["walk_len"][b])].tolist())
            assert not walked & promoted_pages, \
                "fused walk visited a near-resident page"
            mapped = {int(p) for p in world.pt[b] if p >= 0}
            assert walked <= mapped, "fused walk visited an unmapped page"
            # live non-promoted rows == the walk's row count
            want_rows = sum(
                min(max(int(world.pos[b]) - j * PAGE, 0), PAGE)
                for j in range(N_PAGES)
                if world.pt[b, j] >= 0 and int(world.pt[b, j])
                not in promoted_pages)
            got_rows = int(np.asarray(meta["walk_live"])[b].sum())
            assert got_rows == want_rows


class TestPagedReadPathPieces:
    def test_gather_kernel_read_path_parity(self):
        """The Pallas paged-gather far view equals the XLA take path."""
        world = PagedWorld(3, "SC", share=True)
        for op in ("admit", "admit", "decode", "migrate", "decode",
                   "migrate"):
            getattr(world, op)()
        pos = jnp.asarray(world.pos, jnp.int32)
        got_xla = tkv.paged_tiered_attention(world.cache, world.q, pos,
                                             world.cfg)
        kcfg = TieredKVConfig(**{**world.cfg.__dict__, "gather_kernel": True})
        got_krn = tkv.paged_tiered_attention(world.cache, world.q, pos, kcfg)
        np.testing.assert_allclose(np.asarray(got_krn), np.asarray(got_xla),
                                   rtol=1e-6, atol=1e-6)

    def test_promoted_shared_page_serves_all_tenants(self):
        """Two slots sharing a page promoted to the global near tier must
        BOTH read it from the near buffer (far mask excludes it for both)."""
        cfg = TieredKVConfig(page=PAGE, near_pages=2, interval=2,
                             max_promotions=2, policy="SC")
        cache = tkv.init_paged_cache(cfg, 2, 2, 6, HKV, HD,
                                     dtype=jnp.float32)
        rng = np.random.default_rng(0)
        cache["page_table"] = jnp.asarray([[0, 1], [0, 2]], jnp.int32)
        for pid in range(3):
            cache["pool_k"] = cache["pool_k"].at[pid].set(
                jnp.asarray(rng.normal(size=(PAGE, HKV, HD)), jnp.float32))
            cache["pool_v"] = cache["pool_v"].at[pid].set(
                jnp.asarray(rng.normal(size=(PAGE, HKV, HD)), jnp.float32))
        q = jnp.asarray(rng.normal(size=(2, HKV * 2, HD)), jnp.float32)
        pos = jnp.asarray([2 * PAGE, 2 * PAGE], jnp.int32)
        cache = tkv.paged_plan_and_migrate(cache, q, pos, cfg)
        sop = np.asarray(cache["slot_of_page"])
        assert sop[0] >= 0, "aggregate-scored shared page not promoted"
        far_live, near_live = tkv._paged_masks(cache, pos, cfg)
        far_live = np.asarray(far_live).reshape(2, 2, PAGE)
        assert not far_live[:, 0].any(), \
            "promoted shared page must be far-masked for every tenant"
        near_live = np.asarray(near_live).reshape(2, 2, PAGE)
        assert near_live[:, sop[0]].all(), \
            "promoted shared page must be near-live for every tenant"
        # and the merged read stays exact for both tenants
        got = tkv.paged_tiered_attention(cache, q, pos, cfg)
        k = np.asarray(cache["pool_k"])[np.asarray([[0, 1], [0, 2]])]
        v = np.asarray(cache["pool_v"])[np.asarray([[0, 1], [0, 2]])]
        k = jnp.asarray(k.reshape(2, 2 * PAGE, HKV, HD))
        v = jnp.asarray(v.reshape(2, 2 * PAGE, HKV, HD))
        want = ref.decode_attention_ref(q[:, None], k, v, pos)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_append_at_capacity_is_dropped_not_clamped(self):
        """A token append at pos == capacity must be dropped: a clamped
        page index would silently overwrite the slot's LAST page."""
        cfg = TieredKVConfig(page=PAGE, near_pages=2, interval=2,
                             max_promotions=1, policy="SC")
        cache = tkv.init_paged_cache(cfg, 1, 2, 4, HKV, HD,
                                     dtype=jnp.float32)
        cache["page_table"] = jnp.asarray([[0, 1]], jnp.int32)
        before = np.asarray(cache["pool_k"]).copy()
        k1 = jnp.ones((1, 1, HKV, HD), jnp.float32)
        out = tkv.paged_append_token(cache, k1, k1,
                                     jnp.asarray([2 * PAGE], jnp.int32), cfg)
        np.testing.assert_array_equal(np.asarray(out["pool_k"]), before)

    def test_incomplete_page_never_promotes(self):
        """The decode frontier page (partially written) must not enter the
        near tier for any slot, even when its attention mass dominates."""
        cfg = TieredKVConfig(page=PAGE, near_pages=2, interval=4,
                             max_promotions=2, policy="SC")
        cache = tkv.init_paged_cache(cfg, 1, 2, 4, HKV, HD,
                                     dtype=jnp.float32)
        cache["page_table"] = jnp.asarray([[0, 1]], jnp.int32)
        rng = np.random.default_rng(1)
        cache["pool_k"] = jnp.asarray(rng.normal(
            size=cache["pool_k"].shape), jnp.float32)
        cache["pool_v"] = jnp.asarray(rng.normal(
            size=cache["pool_v"].shape), jnp.float32)
        q = jnp.asarray(rng.normal(size=(1, HKV * 2, HD)), jnp.float32)
        pos = jnp.asarray([PAGE + 3], jnp.int32)     # page 1 mid-write
        for _ in range(3):
            cache = tkv.paged_plan_and_migrate(cache, q, pos, cfg)
        sop = np.asarray(cache["slot_of_page"])
        assert sop[0] >= 0, "complete page 0 should promote"
        assert sop[1] < 0, "incomplete frontier page must stay far"


class TestPagePool:
    def test_refcount_lifecycle_and_retention(self):
        pool = PagePool(4)
        a = pool.allocate(2)
        assert pool.refcount[a].tolist() == [1, 1]
        pool.acquire(a)
        assert pool.refcount[a].tolist() == [2, 2]
        assert pool.release(a) == []                 # still referenced
        pool.retain(a[:1])
        freed = pool.release(a)
        assert freed == [a[1]]                       # a[0] retained by index
        assert pool.refcount[a[0]] == 0 and pool.cached[a[0]]
        assert pool.drop_cached(a[:1]) == [a[0]]
        assert pool.available() == 4

    def test_allocate_exhaustion_raises(self):
        pool = PagePool(2)
        pool.allocate(2)
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.allocate(1)


class TestRadixPrefixCache:
    def test_match_is_page_granular_and_suffix_preserving(self):
        pool = PagePool(8)
        trie = RadixPrefixCache(pool, 4)
        toks = np.arange(12)
        pages = pool.allocate(3)
        trie.insert(toks, pages)
        assert trie.match(toks) == pages[:2], \
            "a full match must still leave >= 1 suffix token"
        assert trie.match(toks[:9]) == pages[:2]
        assert trie.match(toks[:8]) == pages[:1]
        assert trie.match(np.concatenate([toks[:4], 99 + toks[:8]])) \
            == pages[:1]
        assert trie.match(99 + toks) == []

    def test_lru_leaf_eviction_under_pressure(self):
        pool = PagePool(4)
        trie = RadixPrefixCache(pool, 2)
        a = pool.allocate(2)
        trie.insert(np.asarray([1, 2, 3, 4]), a)      # chain of 2 pages
        pool.release(a)                               # cached, refcount 0
        b = pool.allocate(1)
        trie.insert(np.asarray([5, 6]), b)
        pool.release(b)
        trie.match(np.asarray([5, 6, 7]))             # freshen b's page
        pages, evicted = trie.allocate(3)             # needs evictions
        assert len(pages) == 3
        # leaf-first: the chain's LEAF page [3,4] goes before its parent;
        # the freshened [5,6] page is the most-recently-used
        assert evicted[0] == a[1]
        assert trie.match(np.asarray([5, 6, 7])) in ([b[0]], []) \
            or True  # b may have been evicted under full pressure
        assert trie.stats.evictions >= 2
