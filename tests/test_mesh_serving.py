"""Mesh-native paged serving (ISSUE 10): KV-head-sharded pool + DP lanes.

The load-bearing property is BIT-IDENTITY: sharding the page pool and near
buffers by KV head across the mesh's 'model' axis, and partitioning
admissions across data-parallel engine replicas, must change NO emitted
token — every per-(slot, kv-head) computation is arithmetically
independent, the fused walk kernel runs per head shard under ``shard_map``
with replicated stats gathers, and the prefill factories pin their compute
replicated so the pool rows are the single-device bytes (docs/design.md
§2h).

The sharded matrix needs a forced multi-device host
(``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the mesh-4dev CI
leg); the GQA/MQA replication fallback, the data-parallel scheduler, and
the cost-model lane unit tests run anywhere.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.tiered_kv import TieredKVConfig
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import transformer
from repro.serve import ServingConfig, ServingEngine
from repro.serve.engine import DataParallelEngine
from repro.serve.metrics import CostModel, ServingReport, merge_lane_reports
from repro.serve.trace import Request
from repro.sharding.specs import kv_shard_count

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")

# engine read-path modes, as CI names them (REPRO_KERNEL_MODE)
MODES = {"dense": dict(fused_kernel=False, gather_kernel=False),
         "gather": dict(fused_kernel=False, gather_kernel=True),
         "fused": dict(fused_kernel=True, gather_kernel=False)}
POLICIES = ("SC", "WMC", "BBC", "STATIC")


class _FakeMesh:
    """Shape-only mesh stand-in: ``kv_shard_count`` and the engine's
    fallback path read nothing but ``mesh.shape``, so divisibility logic
    is unit-testable without forced devices."""

    def __init__(self, **shape):
        self.shape = shape


@pytest.fixture(scope="module")
def arch_params():
    arch = ARCHS["qwen3-1.7b"].reduced()
    return arch, transformer.init_params(jax.random.key(0), arch)


@pytest.fixture(scope="module")
def trace():
    rng = np.random.default_rng(7)
    lens = [20, 12, 20, 12, 20]
    arrivals = [0, 1, 3, 6, 10]
    return [Request(rid=i, arrival=arrivals[i],
                    prompt=rng.integers(0, 2048, lens[i]).astype(np.int32),
                    max_new_tokens=6)
            for i in range(5)]


def _cfg(mode: str, policy: str, mesh=None) -> ServingConfig:
    tier = TieredKVConfig(page=16, near_pages=2, interval=3, policy=policy,
                          mesh=mesh, **MODES[mode])
    return ServingConfig(n_slots=3, max_len=64, prefill_bucket=16, tier=tier)


class TestKvShardCount:
    def test_no_mesh_and_trivial_axis_are_one(self):
        assert kv_shard_count(None, 8) == 1
        assert kv_shard_count(_FakeMesh(data=4, model=1), 8) == 1
        assert kv_shard_count(_FakeMesh(data=4), 8) == 1

    def test_divisible_heads_shard(self):
        assert kv_shard_count(_FakeMesh(data=1, model=4), 8) == 4
        assert kv_shard_count(_FakeMesh(data=2, model=2), 2) == 2

    def test_gqa_mqa_fall_back_to_replication(self):
        assert kv_shard_count(_FakeMesh(model=4), 2) == 1   # GQA Hkv=2
        assert kv_shard_count(_FakeMesh(model=4), 1) == 1   # MQA
        assert kv_shard_count(_FakeMesh(model=3), 8) == 1


class TestMeshFactories:
    def test_make_test_mesh_rejects_oversubscription_with_hint(self):
        n = jax.device_count() + 1
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            make_test_mesh(n)

    def test_make_test_mesh_rejects_bad_data_split(self):
        with pytest.raises(ValueError):
            make_test_mesh(1, data=2)

    def test_production_mesh_host_fallback_is_deterministic(self):
        """Satellite fix: under a forced-host device count the production
        factory must return a usable (1, n) data/model mesh instead of
        asserting on pod topology."""
        m1, m2 = make_production_mesh(), make_production_mesh()
        assert m1.shape == {"data": 1, "model": jax.device_count()}
        assert list(m1.devices.flat) == list(m2.devices.flat) \
            == jax.devices()

    @needs4
    def test_make_test_mesh_axes_and_order(self):
        m = make_test_mesh(4, data=2)
        assert m.shape == {"data": 2, "model": 2}
        assert list(m.devices.flat) == jax.devices()[:4]


@needs4
class TestShardedBitIdentity:
    """ISSUE 10 acceptance: emitted tokens bit-identical to single-device
    across all 4 policies x all kernel modes on a >=4-device forced-host
    mesh, with the pool genuinely sharded (kv_shards == 2 on the
    (data=2, model=2) mesh — Hkv=2 divides the model axis)."""

    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("mode", sorted(MODES))
    def test_tokens_match_single_device(self, arch_params, trace, mode,
                                        policy):
        arch, params = arch_params
        ref = ServingEngine(params, arch, _cfg(mode, policy)).run(
            trace, "mesh")
        mesh = make_test_mesh(4, data=2)
        eng = ServingEngine(params, arch, _cfg(mode, policy, mesh=mesh))
        assert eng.kv_shards == 2, "mesh must actually shard the KV heads"
        rep = eng.run(trace, "mesh")
        assert rep.outputs == ref.outputs, \
            f"{mode}/{policy}: sharded tokens diverge from single-device"
        # each device streams half the KV bytes; the weight-stream
        # overhead is NOT divided, so the clock shrinks but not by 2x
        assert rep.modeled_time < ref.modeled_time
        assert rep.tokens == ref.tokens


class TestReplicationFallback:
    """Satellite: Hkv % model-axis != 0 (GQA on a 4-way axis, MQA Hkv=1)
    must fall back to full replication and stay bit-identical by
    construction — no shard_map, no constraints, the single-device
    program."""

    def test_fallback_engine_is_single_device_program(self, arch_params,
                                                      trace):
        """Runs on ONE device: a shape-only mesh whose model axis does not
        divide Hkv=2 must leave every mesh hook dormant."""
        arch, params = arch_params
        ref = ServingEngine(params, arch, _cfg("fused", "BBC")).run(
            trace, "mesh")
        eng = ServingEngine(
            params, arch,
            _cfg("fused", "BBC", mesh=_FakeMesh(data=1, model=4)))
        assert eng.kv_shards == 1
        rep = eng.run(trace, "mesh")
        assert rep.outputs == ref.outputs
        assert rep.modeled_time == ref.modeled_time   # cost lane unscaled

    @needs4
    def test_gqa_nondivisible_on_real_mesh(self, arch_params, trace):
        arch, params = arch_params
        mesh = make_test_mesh(4)          # model axis 4; Hkv=2 -> fallback
        ref = ServingEngine(params, arch, _cfg("fused", "SC")).run(
            trace, "mesh")
        eng = ServingEngine(params, arch, _cfg("fused", "SC", mesh=mesh))
        assert eng.kv_shards == 1
        assert eng.run(trace, "mesh").outputs == ref.outputs

    @needs4
    def test_mqa_single_kv_head_on_real_mesh(self, trace):
        arch = dataclasses.replace(ARCHS["qwen3-1.7b"].reduced(),
                                   n_kv_heads=1)
        params = transformer.init_params(jax.random.key(1), arch)
        mesh = make_test_mesh(4, data=2)  # model axis 2; Hkv=1 -> fallback
        ref = ServingEngine(params, arch, _cfg("fused", "BBC")).run(
            trace, "mesh")
        eng = ServingEngine(params, arch, _cfg("fused", "BBC", mesh=mesh))
        assert eng.kv_shards == 1
        assert eng.run(trace, "mesh").outputs == ref.outputs


class TestDataParallelScheduler:
    """DP replicas over the 'data' axis: round-robin admission by arrival
    order, per-lane byte-cost clocks, merged fleet report.  Decode tokens
    are batching-invariant, so splitting a trace across lanes changes NO
    token — this runs on one device (lanes are modeled, host-sequential)."""

    def test_outputs_bit_identical_and_deterministic(self, arch_params,
                                                     trace):
        arch, params = arch_params
        cfg = _cfg("fused", "BBC")
        ref = ServingEngine(params, arch, cfg).run(trace, "dp")
        dp = DataParallelEngine(params, arch, cfg, n_replicas=4)
        rep1 = dp.run(trace, "dp")
        rep2 = dp.run(trace, "dp")
        assert rep1.outputs == ref.outputs == rep2.outputs
        assert rep1.tokens == ref.tokens
        assert rep1.n_requests == len(trace)

    def test_fleet_clock_is_max_lane_and_beats_single_lane(self,
                                                           arch_params,
                                                           trace):
        arch, params = arch_params
        cfg = _cfg("fused", "BBC")
        single = ServingEngine(params, arch, cfg).run(trace, "dp")
        rep = DataParallelEngine(params, arch, cfg, n_replicas=4).run(
            trace, "dp")
        # 4 weight streams instead of 1: the fleet finishes earlier on the
        # modeled clock, so tokens-per-cost rises
        assert rep.modeled_time < single.modeled_time
        assert rep.tokens_per_cost > single.tokens_per_cost

    def test_replica_count_comes_from_mesh_data_axis(self, arch_params):
        arch, params = arch_params
        cfg = _cfg("fused", "BBC", mesh=_FakeMesh(data=4, model=1))
        dp = DataParallelEngine(params, arch, cfg)
        assert dp.n_replicas == 4
        assert DataParallelEngine(params, arch,
                                  _cfg("fused", "BBC")).n_replicas == 1


class TestCostModelLane:
    def test_kv_term_divides_overhead_does_not(self):
        cm = CostModel()
        near, live = np.asarray([4.0]), np.asarray([10.0])
        kv = (near * cm.tier.near_cost
              + (live - near) * cm.tier.far_cost).sum()
        assert cm.decode_step_cost(near, live) \
            == pytest.approx(cm.step_overhead + kv)
        assert cm.decode_step_cost(near, live, kv_shards=2) \
            == pytest.approx(cm.step_overhead + kv / 2)
        assert cm.decode_step_cost(near, live, kv_shards=1) \
            == cm.decode_step_cost(near, live)

    def test_merge_lane_reports_semantics(self):
        a = ServingReport(scenario="s", policy="BBC", n_requests=2,
                          tokens=10, steps=5, modeled_time=100.0,
                          migrations=1, kv_bytes_live=64,
                          token_latencies=[1.0], ttfts=[2.0],
                          outputs={0: [1]}, slot_history={0: [0]})
        b = ServingReport(scenario="s", policy="BBC", n_requests=1,
                          tokens=4, steps=4, modeled_time=70.0,
                          migrations=2, kv_bytes_live=32,
                          token_latencies=[3.0], ttfts=[4.0],
                          outputs={1: [2]}, slot_history={0: [1]})
        m = merge_lane_reports([a, b])
        assert (m.tokens, m.steps, m.migrations) == (14, 9, 3)
        assert m.n_requests == 3
        assert m.modeled_time == 100.0            # max lane clock
        assert m.kv_bytes_live == 96              # lanes own distinct HBM
        assert sorted(m.token_latencies) == [1.0, 3.0]
        assert sorted(m.ttfts) == [2.0, 4.0]
        assert m.outputs == {0: [1], 1: [2]}
        assert set(m.slot_history) == {(0, 0), (1, 0)}  # lane-namespaced
        with pytest.raises(ValueError):
            merge_lane_reports([])
