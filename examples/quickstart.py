"""Quickstart: train a reduced qwen3 on synthetic data, with checkpointing,
straggler telemetry, and resume — the full production loop at toy scale.

  PYTHONPATH=src python examples/quickstart.py [--steps 60] [--arch qwen3-1.7b]
"""

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import InputShape
from repro.configs.registry import ARCHS
from repro.data.pipeline import SyntheticLM
from repro.launch import train as T
from repro.optim import adamw
from repro.runtime.fault_tolerance import StragglerDetector


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    arch = ARCHS[args.arch].reduced()
    shape = InputShape("quickstart", seq_len=128, global_batch=8, kind="train")
    cfg = T.TrainConfig(remat="none",
                        adamw=adamw.AdamWConfig(lr=1e-3),
                        warmup_steps=10, total_steps=args.steps)

    params, opt_state = T.init_all(jax.random.key(0), arch, cfg)
    data = SyntheticLM(arch, shape)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    det = StragglerDetector()

    start = 0
    if ckpt.latest_step() is not None:
        (params, opt_state), extra = ckpt.restore((params, opt_state))
        start = extra["data_step"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(T.make_train_step(arch, cfg), donate_argnums=(0, 1))
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jax.numpy.asarray(v) for k, v in
                 data.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        det.observe_step({"host0": dt})
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state),
                      extra={"data_step": step + 1}, blocking=False)
    ckpt.wait()
    print("done; checkpoints:", ckpt.all_steps())


if __name__ == "__main__":
    main()
