"""Tiered-memory runtime tests: partition exactness, vectorized-policy
equivalence with the reference oracle, channel-free migration (no
collectives), hit rates — all four policies on the JAX substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiered_embedding as te, tiered_kv as tkv
from repro.tier import TierCosts, jax_engine
from repro.tier.reference import CacheState, make_policy
from repro.kernels import ref


def _mk_cache(B=2, T=512, Hkv=2, hd=32, page=64, near_pages=3, seed=0,
              policy="BBC"):
    cfg = tkv.TieredKVConfig(page=page, near_pages=near_pages, interval=8,
                             max_promotions=2, policy=policy)
    ks = jax.random.split(jax.random.key(seed), 2)
    k = jax.random.normal(ks[0], (B, T, Hkv, hd), jnp.float32) * 0.5
    v = jax.random.normal(ks[1], (B, T, Hkv, hd), jnp.float32) * 0.5
    return tkv.init_tiered_cache(k, v, cfg), cfg


class TestTieredKV:
    def test_attention_matches_monolithic_before_and_after_migration(self):
        """The core invariant: two-tier attention == plain attention over the
        full cache, regardless of what BBC promoted."""
        cache, cfg = _mk_cache()
        B, T, Hkv, hd = cache["far_k"].shape
        H = Hkv * 2
        q = jax.random.normal(jax.random.key(7), (B, H, hd), jnp.float32)
        pos = jnp.asarray(T // 2 + 17, jnp.int32)

        want = ref.decode_attention_ref(
            q[:, None], cache["far_k"], cache["far_v"],
            jnp.full((B,), pos, jnp.int32))[:, 0]

        got0 = tkv.tiered_attention(cache, q, pos, cfg)
        np.testing.assert_allclose(np.asarray(got0), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

        # drive several BBC intervals, then check again
        for _ in range(4):
            cache = tkv.plan_and_migrate(cache, q, pos, cfg)
        assert int(cache["migrations"]) > 0, "BBC should promote hot pages"
        got1 = tkv.tiered_attention(cache, q, pos, cfg)
        np.testing.assert_allclose(np.asarray(got1), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_promotes_high_mass_pages(self):
        """Pages receiving most attention mass must end up in the near tier."""
        cache, cfg = _mk_cache(seed=1)
        B, T, Hkv, hd = cache["far_k"].shape
        H = Hkv * 2
        # concentrate attention on page 1: make its keys parallel to q
        q = jnp.ones((B, H, hd), jnp.float32)
        hot = slice(1 * cfg.page, 2 * cfg.page)
        far_k = cache["far_k"].at[:, hot].set(3.0)
        cache["far_k"] = far_k
        pos = jnp.asarray(T, jnp.int32) - 1
        for _ in range(4):
            cache = tkv.plan_and_migrate(cache, q, pos, cfg)
        assert bool((cache["slot_of_page"][:, 1] >= 0).all()), \
            cache["slot_of_page"]

    def test_migration_emits_no_collectives(self):
        """IST analogue: the migration program must contain zero collective
        ops (the paper's channel-free property).  Routed through the shared
        ``repro.analysis`` HLO helpers; ``python -m repro.analysis``
        enforces the same pin on the registered migration target
        (no-collectives pass)."""
        from repro.analysis.walker import (COLLECTIVE_OPS, hlo_ops_present,
                                           lower_hlo_text)
        cache, cfg = _mk_cache()
        B, T, Hkv, hd = cache["far_k"].shape
        q = jnp.ones((B, Hkv * 2, hd), jnp.float32)
        pos = jnp.asarray(T - 1, jnp.int32)
        hlo = lower_hlo_text(
            lambda c, q, p: tkv.plan_and_migrate(c, q, p, cfg),
            cache, q, pos)
        present = hlo_ops_present(hlo, COLLECTIVE_OPS)
        assert not present, f"migration HLO contains {present}"

    def test_append_token(self):
        cache, cfg = _mk_cache()
        B, T, Hkv, hd = cache["far_k"].shape
        k_new = jnp.full((B, 1, Hkv, hd), 9.0)
        cache2 = tkv.append_token(cache, k_new, k_new, jnp.asarray(5))
        np.testing.assert_allclose(cache2["far_k"][:, 5], 9.0)
        np.testing.assert_allclose(cache2["far_k"][:, 4],
                                   cache["far_k"][:, 4])

    @pytest.mark.parametrize("policy", ["SC", "WMC", "BBC", "STATIC"])
    def test_all_policies_preserve_attention_exactness(self, policy):
        """Acceptance: every paper policy runs on the KV substrate through
        the one engine, and two-tier attention stays exact regardless of
        what it promoted."""
        cache, cfg = _mk_cache(policy=policy)
        B, T, Hkv, hd = cache["far_k"].shape
        q = jax.random.normal(jax.random.key(9), (B, Hkv * 2, hd))
        # mid-decode position: incomplete pages exist, so the engines'
        # complete-page guards are load-bearing for exactness
        pos = jnp.asarray(T // 2 + 17, jnp.int32)
        if policy == "STATIC":
            profile = tkv.page_masses(q, cache, pos, cfg)
            cache = tkv.preload_static_kv(cache, profile, pos, cfg)
            assert bool((cache["page_of_slot"] >= 0).any())
        for _ in range(3):
            cache = tkv.plan_and_migrate(cache, q, pos, cfg)
        if policy in ("SC", "WMC", "BBC"):
            assert int(cache["migrations"]) > 0, policy
        want = ref.decode_attention_ref(
            q[:, None], cache["far_k"], cache["far_v"],
            jnp.full((B,), pos, jnp.int32))[:, 0]
        got = tkv.tiered_attention(cache, q, pos, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_static_kv_never_migrates_at_runtime(self):
        cache, cfg = _mk_cache(policy="STATIC")
        B, T, Hkv, hd = cache["far_k"].shape
        q = jnp.ones((B, Hkv * 2, hd))
        pos = jnp.asarray(T - 1, jnp.int32)
        cache = tkv.preload_static_kv(cache, tkv.page_masses(q, cache, pos, cfg),
                                      pos, cfg)
        placed = np.asarray(cache["page_of_slot"]).copy()
        for _ in range(3):
            cache = tkv.plan_and_migrate(cache, q, pos, cfg)
        assert int(cache["migrations"]) == 0
        np.testing.assert_array_equal(np.asarray(cache["page_of_slot"]),
                                      placed)


class TestVectorizedBBCEquivalence:
    def test_matches_object_policy_on_shared_trace(self):
        """The vectorized BBC and the DRAM simulator's object BBC make the
        same promotion decisions on the same activation stream."""
        costs_obj = TierCosts(near_cost=1.0, far_cost=4.0, migrate_cost=3.0)
        costs_vec = TierCosts(
            near_cost=1.0, far_cost=4.0, migrate_cost=3.0, hysteresis=2.0,
            min_score=2.0, decay=0.9)
        N, C = 32, 4
        rng = np.random.default_rng(0)
        # Zipfian activation stream over N rows, processed in intervals.
        ranks = np.arange(1, N + 1)
        p = ranks ** -1.5
        p /= p.sum()
        stream = rng.choice(N, size=400, p=p)

        # object policy (decide per access, like the DRAM controller)
        pol = make_policy("BBC", costs_obj, decay=0.9)
        pol.min_score = 2.0
        st = CacheState(capacity=C)
        for i, row in enumerate(stream):
            in_near = st.hit(int(row))
            pol.on_access(st, int(row), float(i), False, in_near,
                          activated=True)
            if not in_near:
                d = pol.decide(st, int(row), float(i), bank_idle=True)
                if d.promote:
                    pol.apply_promotion(st, int(row), d)
            if i % 16 == 15:
                pol.decay_scores(st)

        # vectorized policy (interval batches)
        scores = jnp.zeros((N,), jnp.float32)
        slot_of = -jnp.ones((N,), jnp.int32)
        row_of = -jnp.ones((C,), jnp.int32)
        for start in range(0, 400, 16):
            batch = stream[start:start + 16]
            counts = np.bincount(batch, minlength=N).astype(np.float32)
            scores = jax_engine.ema_update(scores, jnp.asarray(counts),
                                           costs_vec)
            rows, slots, valid = jax_engine.plan_promotions(
                scores, slot_of, row_of, costs_vec, max_promotions=2)
            slot_of, row_of = jax_engine.apply_promotions(
                slot_of, row_of, rows, slots, valid)

        vec_cached = set(np.asarray(row_of)[np.asarray(row_of) >= 0].tolist())
        obj_cached = set(st.lookup.keys())
        # Both must capture the Zipf head; demand >= 50% agreement and that
        # the single hottest row is cached by both.
        assert 0 in vec_cached and 0 in obj_cached
        overlap = len(vec_cached & obj_cached) / max(len(obj_cached), 1)
        assert overlap >= 0.5, (vec_cached, obj_cached)

    def test_mapping_arrays_stay_consistent(self):
        N, C = 16, 3
        costs = TierCosts(1.0, 4.0, 2.0, min_score=1.0)
        scores = jnp.zeros((N,), jnp.float32)
        slot_of = -jnp.ones((N,), jnp.int32)
        row_of = -jnp.ones((C,), jnp.int32)
        rng = np.random.default_rng(1)
        for step in range(30):
            counts = np.zeros(N, np.float32)
            counts[rng.integers(0, N, 6)] += 2.0
            scores = jax_engine.ema_update(scores, jnp.asarray(counts), costs)
            rows, slots, valid = jax_engine.plan_promotions(
                scores, slot_of, row_of, costs, 2)
            slot_of, row_of = jax_engine.apply_promotions(
                slot_of, row_of, rows, slots, valid)
            so, ro = np.asarray(slot_of), np.asarray(row_of)
            for slot, row in enumerate(ro):
                if row >= 0:
                    assert so[row] == slot
            cached_rows = [r for r in range(N) if so[r] >= 0]
            for r in cached_rows:
                assert ro[so[r]] == r
            assert len(cached_rows) == len({so[r] for r in cached_rows})


class TestTieredEmbedding:
    def test_lookup_exact(self):
        cfg = te.TieredEmbeddingConfig(near_rows=8, max_promotions=4)
        V, D = 64, 16
        table = jax.random.normal(jax.random.key(0), (V, D), jnp.float32)
        state = te.init_state(table, cfg)
        toks = jnp.asarray([3, 5, 3, 60, 1], jnp.int32)
        out, hits = te.lookup(table, state, toks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(table[toks]),
                                   rtol=1e-6)
        assert not bool(hits.any())  # nothing promoted yet

    def test_zipf_stream_reaches_high_hit_rate(self):
        cfg = te.TieredEmbeddingConfig(near_rows=32, max_promotions=16)
        V, D = 1024, 8
        table = jax.random.normal(jax.random.key(1), (V, D), jnp.float32)
        state = te.init_state(table, cfg)
        rng = np.random.default_rng(2)
        ranks = np.arange(1, V + 1)
        p = ranks ** -1.4
        p /= p.sum()
        for _ in range(20):
            toks = jnp.asarray(rng.choice(V, size=256, p=p), jnp.int32)
            state = te.record_and_migrate(table, state, toks, cfg)
        toks = jnp.asarray(rng.choice(V, size=512, p=p), jnp.int32)
        out, hits = te.lookup(table, state, toks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(table[toks]),
                                   rtol=1e-6)
        assert float(hits.mean()) > 0.6, float(hits.mean())
        assert int(state["migrations"]) > 0

    @pytest.mark.parametrize("policy", ["SC", "WMC", "BBC", "STATIC"])
    def test_all_policies_lookup_exact(self, policy):
        """Acceptance: every paper policy runs on the embedding substrate
        through the one engine; lookups stay exact and locality-friendly
        policies reach a meaningful hit rate."""
        cfg = te.TieredEmbeddingConfig(near_rows=32, max_promotions=16,
                                       policy=policy)
        V, D = 512, 8
        table = jax.random.normal(jax.random.key(3), (V, D), jnp.float32)
        state = te.init_state(table, cfg)
        rng = np.random.default_rng(4)
        ranks = np.arange(1, V + 1)
        p = ranks ** -1.4
        p /= p.sum()
        if policy == "STATIC":
            profile = np.bincount(rng.choice(V, size=4096, p=p),
                                  minlength=V).astype(np.float32)
            state = te.preload_static_embedding(table, state,
                                                jnp.asarray(profile), cfg)
        for _ in range(10):
            toks = jnp.asarray(rng.choice(V, size=256, p=p), jnp.int32)
            state = te.record_and_migrate(table, state, toks, cfg)
        toks = jnp.asarray(rng.choice(V, size=512, p=p), jnp.int32)
        out, hits = te.lookup(table, state, toks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(table[toks]),
                                   rtol=1e-6)
        assert float(hits.mean()) > 0.4, (policy, float(hits.mean()))
        if policy == "STATIC":
            assert int(state["migrations"]) == 0
        else:
            assert int(state["migrations"]) > 0

    def test_refresh_after_table_update(self):
        cfg = te.TieredEmbeddingConfig(near_rows=4, max_promotions=4)
        V, D = 32, 4
        table = jnp.ones((V, D))
        state = te.init_state(table, cfg)
        toks = jnp.asarray([2, 2, 2, 9, 9, 9], jnp.int32)
        for _ in range(3):
            state = te.record_and_migrate(table, state, toks, cfg)
        table2 = table * 5.0
        state = te.refresh(table2, state)
        out, hits = te.lookup(table2, state, toks)
        np.testing.assert_allclose(np.asarray(out), 5.0)
        assert bool(hits.all())


class TestCompatShims:
    def test_legacy_modules_reexport_tier_subsystem(self):
        """`repro.core.policies` / `repro.core.tier_policy` stay importable
        as thin shims over `repro.tier` for downstream callers."""
        from repro.core import policies as shim_p, tier_policy as shim_t
        from repro.tier import costs as tier_costs, jax_engine as tier_jax
        from repro.tier import reference as tier_ref
        assert shim_p.make_policy is tier_ref.make_policy
        assert shim_p.CacheState is tier_ref.CacheState
        assert shim_t.TierCosts is tier_costs.TierCosts
        assert shim_t.plan_promotions is tier_jax.plan_promotions
        assert shim_t.apply_promotions is tier_jax.apply_promotions
