"""Core model layers: norms, rotary embeddings, GLU MLP, attention.

Pure-functional JAX: parameters are pytrees of arrays; every layer is a
function ``(params, inputs) -> outputs``.  Training attention uses a chunked
online-softmax (flash-style) formulation so the compiled memory footprint is
O(S * chunk) rather than O(S^2) — this is also the pure-jnp oracle for the
Pallas kernel in ``repro.kernels``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# -- norms -------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


# -- rotary position embeddings ----------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32.

    Adjacent-pair (interleaved / NeoX) rotation: pair (2i, 2i+1) rotates by
    angle pos * theta^(-2i/hd).  Chosen over the half-split convention because
    rotation pairs stay contiguous — a head_dim-sharded tensor rotates fully
    locally under GSPMD (docs/design.md Sec. 5).
    """
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xp = x.astype(jnp.float32).reshape(*x.shape[:-1], hd // 2, 2)
    a, b = xp[..., 0], xp[..., 1]
    out = jnp.stack([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int] = (2, 3, 3)) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the frequency bands are partitioned into
    (temporal, height, width) sections with independent position streams.

    x: (B, S, H, hd); positions: (B, S, 3) int32.
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    bounds = np.cumsum([int(round(half * s / total)) for s in sections])
    bounds[-1] = half
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)  # (half,)

    # For each frequency band, pick the position stream of its section.
    section_of_band = np.zeros(half, dtype=np.int32)
    section_of_band[bounds[0]:bounds[1]] = 1
    section_of_band[bounds[1]:] = 2
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.asarray(section_of_band)[None, None, :].repeat(positions.shape[0], 0)
        .repeat(positions.shape[1], 1),
        axis=-1)  # (B,S,half)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xp = x.astype(jnp.float32).reshape(*x.shape[:-1], half, 2)
    a, b = xp[..., 0], xp[..., 1]
    out = jnp.stack([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# -- MLP ----------------------------------------------------------------------

def swiglu(params: dict, x: jax.Array) -> jax.Array:
    """params: w_gate (D,F), w_up (D,F), w_down (F,D)."""
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, params["w_down"])


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    """2-matrix GELU FFN (StarCoder2 / MusicGen style)."""
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(u), params["w_down"])


# -- attention -----------------------------------------------------------------

def _online_softmax_block(q, k, v, mask, carry):
    """One KV-chunk update of the online-softmax accumulator.

    q: (B,S,H,hd)  k/v: (B,C,Hkv,hd) already head-expanded to H.
    mask: (B,S,H,C) additive (0 or NEG_INF).
    carry: (acc (B,S,H,hd) f32, m (B,S,H) f32, l (B,S,H) f32)
    """
    acc, m, l = carry
    scores = jnp.einsum("bshd,bchd->bshc", q, k).astype(jnp.float32)
    scores = scores + mask
    m_new = jnp.maximum(m, scores.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(scores - m_new[..., None])
    l_new = l * alpha + p.sum(axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bshc,bchd->bshd", p.astype(v.dtype), v).astype(jnp.float32)
    return acc_new, m_new, l_new


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_positions: jax.Array, kv_positions: jax.Array,
                    causal: bool = True, window: int = 0,
                    kv_chunk: int = 1024) -> jax.Array:
    """Chunked online-softmax attention (pure JAX flash formulation).

    q: (B,S,H,hd); k,v: (B,T,Hkv,hd); positions: (B,S)/(B,T) absolute.
    GQA: H must be a multiple of Hkv.  window>0 => sliding-window causal.
    Memory: O(S * kv_chunk) per head instead of O(S * T).
    """
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    groups = H // Hkv
    scale = hd ** -0.5
    q = (q * scale).astype(q.dtype)

    # Expand KV heads once per chunk inside the scan body (cheap view-like op).
    n_chunks = max(1, (T + kv_chunk - 1) // kv_chunk)
    pad = n_chunks * kv_chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=np.iinfo(np.int32).max)
    k = k.reshape(B, n_chunks, kv_chunk, Hkv, hd)
    v = v.reshape(B, n_chunks, kv_chunk, Hkv, hd)
    kv_positions = kv_positions.reshape(B, n_chunks, kv_chunk)

    def body(carry, chunk):
        kc, vc, pc = chunk  # (B,C,Hkv,hd), (B,C,Hkv,hd), (B,C)
        kc = jnp.repeat(kc, groups, axis=2)
        vc = jnp.repeat(vc, groups, axis=2)
        valid = jnp.ones((B, S, 1, kc.shape[1]), dtype=bool)
        if causal:
            valid &= (q_positions[:, :, None, None] >= pc[:, None, None, :])
        if window:
            valid &= (q_positions[:, :, None, None] - pc[:, None, None, :]
                      < window)
        mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        return _online_softmax_block(q, kc, vc, mask, carry), None

    init = (jnp.zeros((B, S, H, hd), jnp.float32),
            jnp.full((B, S, H), NEG_INF, jnp.float32),
            jnp.zeros((B, S, H), jnp.float32))
    (acc, _, l), _ = jax.lax.scan(
        body, init,
        (k.transpose(1, 0, 2, 3, 4), v.transpose(1, 0, 2, 3, 4),
         kv_positions.transpose(1, 0, 2)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def paged_decode_attention(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                           near_k: jax.Array, near_v: jax.Array,
                           meta: dict, mesh=None) -> jax.Array:
    """Single-token attention through the fused paged tier (ISSUE 4).

    The TL-DRAM serving read path: instead of materializing the slot's far
    view and masking it, the Pallas kernel (`kernels.paged_attention`) walks
    the slot's page table in-kernel — one pool load per live, non-promoted
    page — and attends the shared near buffer under per-(slot, near-slot)
    live counts.  ``meta`` is `core.tiered_kv.paged_step_metadata`, computed
    once per decode step and shared by every layer.

    q: (B,1,H,hd); pool_k/pool_v: (P,page,Hkv,hd); near: (C*page,Hkv,hd).
    Returns (B,1,H,hd), exactly standard attention over the live prefix.

    ``mesh``: KV-head-sharded pool/near buffers — the kernel runs per head
    shard under ``shard_map`` and the stats come back replicated
    (bit-identical to single-device; docs/design.md §2h).
    """
    from repro.kernels import ref
    from repro.kernels.paged_attention import paged_attention_stats
    stats = paged_attention_stats(q[:, 0], pool_k, pool_v, near_k, near_v,
                                  meta, mesh=mesh)
    return ref.merge_attention_stats([stats])[:, None].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, window: int = 0) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q: (B,1,H,hd); caches: (B,T,Hkv,hd); pos: scalar int32 — the absolute
    position of the current token — or a ragged (B,) vector of per-sequence
    positions (continuous-batching slot pools).  With window>0 the cache is
    a ring buffer of size T=window whose slot for absolute position p is
    p % window.
    """
    B, _, H, hd = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    groups = H // Hkv
    scale = hd ** -0.5
    # f32-accumulated q·k scores (not bf16-rounded-then-cast): every decode
    # read path — this one, the tiered LSE merges, the fused paged kernel —
    # scores in f32, keeping cross-path logit noise at reduction-order
    # level.  preferred_element_type keeps the bf16 operands un-materialized
    # (bf16 MXU inputs, f32 accumulation); the scale is applied in f32.
    qh = q[:, 0].reshape(B, Hkv, groups, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qh, k_cache,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(T)[None, :]                           # (1,T)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))[:, None]  # (B,1)
    if window:
        abs_pos = pos_b - ((pos_b - slots) % window)  # absolute pos per slot
        valid = ((abs_pos >= 0) & (abs_pos <= pos_b)
                 & (pos_b - abs_pos < window))
    else:
        valid = slots <= pos_b
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    # keep p in f32 for the value matmul: every decode read path (this
    # dense path, the two-tier LSE merges, the fused paged kernel)
    # accumulates p@v in f32, so cross-path logit noise stays at f32
    # reduction-order level (~1e-6) and fused-vs-dense token parity holds
    # bit-for-bit on real traces (tests/test_fused_serving.py)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)
