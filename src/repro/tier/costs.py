"""Unified cost model for the tier engines (docs/tier.md §Costs).

Replaces the old twin dataclasses ``repro.core.policies.PolicyCosts`` and
``repro.core.tier_policy.TierCosts`` with a single definition shared by the
DRAM simulator (nanoseconds) and the TPU runtime (modeled relative byte
costs) — only the ratios matter to the policies.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TierCosts:
    """Latency landscape a tier policy optimizes over.

    near_cost / far_cost : cost of one near- / far-segment access.
    migrate_cost         : cost of one inter-segment transfer (IST).
    hysteresis           : BBC margin multiplier on the migration cost.
    min_score            : BBC minimum decayed activation count — a row must
                           show *sustained* reuse before a migration pays.
    decay                : per-interval EMA decay of activation scores.
    """

    near_cost: float
    far_cost: float
    migrate_cost: float
    hysteresis: float = 2.0
    min_score: float = 2.0
    decay: float = 0.95

    @property
    def saving(self) -> float:
        """Cost saved per near-segment access (the per-activation benefit)."""
        return self.far_cost - self.near_cost

    # Legacy alias used by the object reference policies.
    @property
    def saving_per_access(self) -> float:
        return self.saving
