"""Benchmarks reproducing the paper's tables/figures (TL-DRAM, HPCA'13).

One function per paper artifact; each returns rows of (name, value, ...)
and prints a compact CSV.  ``benchmarks.run`` drives them all.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import area, power, simulator as S, tldram, traces as T

# Suites used for Fig 8 (the paper's high-locality SPEC-like regime) — see
# docs/design.md Sec. 2a: traces are synthetic calibrated mixes.
SUITE_1CORE = [("hot", 1), ("hot", 2), ("hot2", 3), ("hot2", 4),
               ("mixed", 5), ("mixed", 6), ("light", 7), ("hot", 8)]
SUITE_2CORE = [(("hot", "mixed"), 1), (("hot2", "hot"), 2),
               (("mixed", "hot2"), 3), (("hot", "light"), 4)]
SUITE_4CORE = [(("hot", "mixed", "hot2", "light"), 1),
               (("hot", "hot2", "mixed", "mixed"), 2),
               (("hot2", "hot", "light", "mixed"), 3)]


def fig3_latency_vs_die_size():
    """Fig 3: tRCD/tRC and die size vs cells-per-bitline."""
    rows = []
    for n, d in area.fig3_tradeoff().items():
        rows.append(("fig3", n, round(d["t_rcd_ns"], 2), round(d["t_rc_ns"], 2),
                     round(d["die_area_norm"], 2)))
    return rows


def fig5_segment_latency_sweep():
    """Fig 5a/5b: near/far latency vs segment length."""
    rows = []
    sweep = tldram.segment_length_sweep(near_lengths=(16, 32, 64, 128, 256))
    for n, t in sorted(sweep["near"].items()):
        rows.append(("fig5a_near", n, round(t.t_rcd, 2), round(t.t_rc, 2)))
    for n, t in sorted(sweep["far"].items()):
        rows.append(("fig5b_far", n, round(t.t_rcd, 2), round(t.t_rc, 2)))
    return rows


def table1_summary():
    """Table 1: latency / power / die-size for the four design points."""
    timings = tldram.table1_model(calibrated=True)
    pw = power.table1_power_norm()
    ar = area.table1_area_norm()
    rows = []
    for name in ("short_32", "long_512", "near_32", "far_480"):
        rows.append(("table1", name, round(timings[name].t_rc, 1),
                     round(pw[name], 2),
                     round(ar.get(name, ar["segmented"]), 2)))
    return rows


def _run_pair(mix, n=15000, seed=1, policy="BBC", near_rows=32):
    tr = T.make_mix(mix, n_requests=n, seed=seed)
    base = S.simulate(S.SimConfig(device=S.DeviceConfig(kind="commodity")), tr)
    tl = S.simulate(S.SimConfig(device=S.DeviceConfig(
        kind="tldram", policy=policy, near_rows=near_rows)), tr)
    return base, tl


def fig8_perf_and_power(n_requests=15000):
    """Fig 8: IPC improvement and power delta, 1/2/4-core, BBC."""
    rows = []
    for label, suite in (("1-core", SUITE_1CORE), ("2-core", SUITE_2CORE),
                         ("4-core", SUITE_4CORE)):
        d_ipc, d_pow, d_energy, hits = [], [], [], []
        for mix, seed in suite:
            mix = (mix,) if isinstance(mix, str) else mix
            base, tl = _run_pair(mix, n=n_requests, seed=seed)
            ipc_b = sum(c.ipc for c in base.cores)
            ipc_t = sum(c.ipc for c in tl.cores)
            d_ipc.append((ipc_t / ipc_b - 1) * 100)
            d_pow.append((tl.power_mw / base.power_mw - 1) * 100)
            d_energy.append((tl.energy_nj / base.energy_nj - 1) * 100)
            hits.append(tl.near_hit_rate)
        rows.append(("fig8", label, round(np.mean(d_ipc), 1),
                     round(np.mean(d_pow), 1), round(np.mean(d_energy), 1),
                     round(np.mean(hits), 3)))
    return rows


def fig8_policy_comparison(n_requests=12000):
    """Sec. 5 policies: SC vs WMC vs BBC vs STATIC.

    The suite deliberately includes a streaming workload: SC/WMC cache every
    far access and thrash on streams, which is exactly why the paper's BBC
    (benefit-gated) wins *overall* despite near-parity on pure-locality
    workloads.  STATIC uses oracle whole-trace profiling (upper bound)."""
    suite = SUITE_1CORE[:3] + [("stream", 9), ("mixed", 5)]
    rows = []
    for policy in ("SC", "WMC", "BBC", "STATIC"):
        d_ipc, hits = [], []
        for mix, seed in suite:
            base, tl = _run_pair((mix,), n=n_requests, seed=seed,
                                 policy=policy)
            d_ipc.append((sum(c.ipc for c in tl.cores)
                          / sum(c.ipc for c in base.cores) - 1) * 100)
            hits.append(tl.near_hit_rate)
        rows.append(("policies", policy, round(np.mean(d_ipc), 1),
                     round(np.mean(hits), 3)))
    return rows


def fig9_capacity_sweep(n_requests=12000):
    """Fig 9: IPC improvement vs near-segment rows (capacity/latency
    trade-off; the paper peaks at 32 rows)."""
    rows = []
    suite = [("capacity", 1), ("capacity", 2), ("hot", 3), ("mixed", 4)]
    for near in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        d = []
        for mix, seed in suite:
            base, tl = _run_pair((mix,), n=n_requests, seed=seed,
                                 near_rows=near)
            d.append((sum(c.ipc for c in tl.cores)
                      / sum(c.ipc for c in base.cores) - 1) * 100)
        rows.append(("fig9", near, round(np.mean(d), 1)))
    return rows


def adversarial_tails(n_requests=12000):
    """Low-locality workloads (the regime where TL-DRAM's far penalty bites —
    reported separately, as the paper's suite is locality-bearing)."""
    rows = []
    for mix in ("stream", "uniform"):
        base, tl = _run_pair((mix,), n=n_requests)
        rows.append(("adversarial", mix,
                     round((sum(c.ipc for c in tl.cores)
                            / sum(c.ipc for c in base.cores) - 1) * 100, 1),
                     round((tl.power_mw / base.power_mw - 1) * 100, 1),
                     round(tl.near_hit_rate, 3)))
    return rows


ALL = [fig3_latency_vs_die_size, fig5_segment_latency_sweep, table1_summary,
       fig8_perf_and_power, fig8_policy_comparison, fig9_capacity_sweep,
       adversarial_tails]


def run_all(quick: bool = False):
    out = []
    for fn in ALL:
        t0 = time.time()
        rows = fn()
        dt = (time.time() - t0) * 1e6 / max(len(rows), 1)
        for r in rows:
            print(",".join(str(x) for x in (r[0], f"{dt:.0f}us") + r[1:]))
        out.extend(rows)
    return out
