"""Vectorized (JAX) Benefit-Based Caching — the TPU-runtime twin of
``repro.core.policies.BenefitBasedCaching``.

Same decision rule, expressed over fixed-shape arrays so it can run jitted on
device every promotion interval (the paper's BBC samples activation counts
per interval in hardware; here the "interval" is N decode steps):

    benefit(row)  = ema_score(row) * saving_per_access
    promote cand  iff benefit(cand) > benefit(victim) + migrate_cost * hyst
    victim        = cached row with the minimum retained benefit

``tests/test_tiered_runtime.py::test_vectorized_bbc_matches_object_policy``
replays the same access stream through both implementations.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TierCosts:
    """Cost landscape in abstract units (ns for DRAM, us-per-access-modeled
    for TPU tiers — only ratios matter)."""

    near_cost: float
    far_cost: float
    migrate_cost: float
    hysteresis: float = 2.0
    min_score: float = 2.0
    decay: float = 0.95

    @property
    def saving(self) -> float:
        return self.far_cost - self.near_cost


def ema_update(scores: jax.Array, activations: jax.Array,
               costs: TierCosts) -> jax.Array:
    """scores, activations: (..., N_rows) — decayed activation counts."""
    return scores * costs.decay + activations


def plan_promotions(scores: jax.Array, cached_slot_of_row: jax.Array,
                    row_of_slot: jax.Array, costs: TierCosts,
                    max_promotions: int):
    """One BBC planning step over a row population.

    scores:             (N,) f32 — EMA activation counts per row.
    cached_slot_of_row: (N,) int32 — near slot per row, -1 if far.
    row_of_slot:        (C,) int32 — far row per near slot, -1 if empty.

    Returns (promote_rows (K,), victim_slots (K,), valid (K,) bool): the rows
    to migrate and the slots to place them in; lock-step with the object
    policy, promotions fill empty slots first, then displace minimum-benefit
    victims when the margin clears the (hysteresis-scaled) migration cost.
    """
    N = scores.shape[0]
    C = row_of_slot.shape[0]
    in_near = cached_slot_of_row >= 0

    cand_scores = jnp.where(in_near, -jnp.inf, scores)
    cand_scores = jnp.where(cand_scores >= costs.min_score, cand_scores,
                            -jnp.inf)
    top_scores, top_rows = jax.lax.top_k(cand_scores, max_promotions)

    slot_empty = row_of_slot < 0
    slot_scores = jnp.where(
        slot_empty, -jnp.inf,
        scores[jnp.maximum(row_of_slot, 0)])                 # (C,)
    # victims: empty slots first (score -inf sorts lowest), then min benefit
    neg_victim_scores, victim_slots = jax.lax.top_k(-slot_scores,
                                                    max_promotions)
    victim_scores = -neg_victim_scores
    victim_scores = jnp.where(jnp.isinf(victim_scores), 0.0, victim_scores)
    victim_is_empty = slot_empty[victim_slots]

    cand_benefit = top_scores * costs.saving
    victim_benefit = victim_scores * costs.saving
    margin = jnp.where(victim_is_empty, costs.migrate_cost,
                       victim_benefit + costs.migrate_cost * costs.hysteresis)
    valid = (cand_benefit > margin) & jnp.isfinite(top_scores)
    return top_rows, victim_slots, valid


def apply_promotions(cached_slot_of_row: jax.Array, row_of_slot: jax.Array,
                     promote_rows: jax.Array, victim_slots: jax.Array,
                     valid: jax.Array):
    """Update the two mapping arrays after a planning step.

    Invalid/sentinel writes are routed to an out-of-bounds index and dropped
    (note: -1 would *wrap* in JAX indexing, so N/C sentinels are used).
    """
    N = cached_slot_of_row.shape[0]
    C = row_of_slot.shape[0]
    old_rows = row_of_slot[victim_slots]
    # evict: clear slot pointers of displaced rows (skip empty slots)
    evict_idx = jnp.where(valid & (old_rows >= 0), old_rows, N)
    cached_slot_of_row = cached_slot_of_row.at[evict_idx].set(-1, mode="drop")
    # place: write new mappings
    place_rows = jnp.where(valid, promote_rows, N)
    cached_slot_of_row = cached_slot_of_row.at[place_rows].set(
        victim_slots, mode="drop")
    slot_idx = jnp.where(valid, victim_slots, C)
    row_of_slot = row_of_slot.at[slot_idx].set(
        jnp.where(valid, promote_rows, -1), mode="drop")
    return cached_slot_of_row, row_of_slot
