"""Reproduce the paper's core results in one run: Table 1, Fig 5 trends,
Fig 8 (IPC/power, 1/2/4-core under BBC), and the Fig 9 capacity sweep.

  PYTHONPATH=src python examples/dram_study.py [--quick]
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

from benchmarks import paper_figures  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    print("== Table 1 (latency / power / die size) ==")
    for r in paper_figures.table1_summary():
        print(f"  {r[1]:10s} tRC={r[2]:6.1f}ns  power={r[3]:.2f}  area={r[4]:.2f}")
    print("   paper:   short 23.1/0.51/3.76  long 52.5/1.00/1.00  "
          "near 23.1/0.51/1.03  far 65.8/1.49/1.03")

    print("\n== Fig 5: segment-length sweeps ==")
    for r in paper_figures.fig5_segment_latency_sweep():
        print(f"  {r[1]} len={r[2]:4d} tRCD={r[3]:6.2f} tRC={r[4]:6.2f}"
              if False else f"  {r[0]} len={r[1]:4d} tRCD={r[2]:6.2f} tRC={r[3]:6.2f}")

    n = 6000 if args.quick else 15000
    print("\n== Fig 8: BBC vs commodity DRAM ==")
    for r in paper_figures.fig8_perf_and_power(n_requests=n):
        print(f"  {r[1]}: IPC {r[2]:+.1f}%  power {r[3]:+.1f}%  "
              f"energy {r[4]:+.1f}%  near-hit {r[5]:.2f}")
    print("   paper:   1-core +12.8% / 2-core +12.3% / 4-core +11.0% IPC; "
          "power -23.6/-26.4/-28.6%")

    print("\n== Sec 5: policy comparison (one repro.tier engine) ==")
    for r in paper_figures.fig8_policy_comparison(n_requests=n):
        print(f"  {r[1]:7s}: IPC {r[2]:+.1f}%  near-hit {r[3]:.2f}")
    print("   BBC wins overall: SC/WMC thrash on the streaming workload")

    print("\n== Fig 9: near-segment capacity sweep ==")
    for r in paper_figures.fig9_capacity_sweep(n_requests=n):
        print(f"  near_rows={r[1]:4d}: IPC {r[2]:+.1f}%")
    print("   paper: peak at 32 rows, declining beyond")


if __name__ == "__main__":
    main()
