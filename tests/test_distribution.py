"""Distribution-layer tests: sharding rules, HLO analyzer, pipeline schedule,
and a one-cell dry-run smoke (subprocess with 512 host devices)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import ARCHS
from repro.launch import hlo_analysis
from repro.models import transformer
from repro.sharding import pipeline as pp
from repro.sharding import specs as sh


class _FakeMesh:
    """Just enough of a Mesh for the pure spec rules."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestShardingRules:
    def test_divisibility_guard(self):
        mesh = _FakeMesh({"data": 16, "model": 16})
        # hymba vocab 32001 divides nothing -> embed replicated
        arch = ARCHS["hymba-1.5b"]
        shapes = jax.eval_shape(
            lambda: transformer.init_params(jax.random.key(0), arch))
        specs = sh.param_specs(shapes, arch, mesh)
        assert specs["embed"] == P(None, None)
        # deepseek 56 heads don't divide 16 -> attention replicated on model
        arch2 = ARCHS["deepseek-coder-33b"]
        shapes2 = jax.eval_shape(
            lambda: transformer.init_params(jax.random.key(0), arch2))
        specs2 = sh.param_specs(shapes2, arch2, mesh)
        assert "model" not in jax.tree.leaves(
            specs2["layers"]["attn"]["wq"], is_leaf=lambda x: True)[0] or True
        assert specs2["layers"]["attn"]["wq"][2] is None
        # but its MLP is TP'd
        assert specs2["layers"]["mlp"]["w_gate"][2] == "model"

    def test_kimi_expert_parallelism(self):
        mesh = _FakeMesh({"data": 16, "model": 16})
        arch = ARCHS["kimi-k2-1t-a32b"]
        shapes = jax.eval_shape(
            lambda: transformer.init_params(jax.random.key(0), arch))
        specs = sh.param_specs(shapes, arch, mesh)
        assert specs["layers"]["moe"]["w_gate"][1] == "model"   # EP on E
        assert specs["layers"]["attn"]["wq"][2] == "model"      # 64 heads / 16

    def test_mamba2_head_aligned(self):
        mesh = _FakeMesh({"data": 16, "model": 16})
        arch = ARCHS["mamba2-1.3b"]
        shapes = jax.eval_shape(
            lambda: transformer.init_params(jax.random.key(0), arch))
        specs = sh.param_specs(shapes, arch, mesh)
        assert specs["layers"]["ssm"]["in_x"][2] == "model"
        # hymba (25 ssm heads) must NOT shard d_inner
        arch2 = ARCHS["hymba-1.5b"]
        shapes2 = jax.eval_shape(
            lambda: transformer.init_params(jax.random.key(0), arch2))
        specs2 = sh.param_specs(shapes2, arch2, mesh)
        assert specs2["layers"]["ssm"]["in_x"][2] is None

    def test_cache_time_axis_sharding(self):
        mesh = _FakeMesh({"data": 16, "model": 16})
        arch = ARCHS["yi-9b"]
        cache_shapes = jax.eval_shape(
            lambda: transformer.init_cache(arch, 128, 32768))
        specs = sh.cache_specs(cache_shapes, arch, mesh)
        assert specs["k"][1] in ("data", ("data",))   # batch over dp
        assert specs["k"][2] == "model"               # time over model


class TestHLOAnalysis:
    def test_scan_trip_count_and_flops(self):
        def f(x, w):
            def body(c, wi):
                return jnp.tanh(c @ wi), ()
            y, _ = jax.lax.scan(body, x, w)
            return y

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32),
            jax.ShapeDtypeStruct((28, 8, 8), jnp.float32)).compile()
        s = hlo_analysis.analyze_module(compiled.as_text())
        assert 28 in s.trip_counts.values()
        expected_dots = 28 * 2 * 8 * 8 * 8
        assert expected_dots <= s.flops <= expected_dots * 1.5

    def test_loop_free_matmul_flops_exact(self):
        def f(a, b):
            return a @ b

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 32), jnp.float32)).compile()
        s = hlo_analysis.analyze_module(compiled.as_text())
        assert s.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.2)

    def test_ring_factors(self):
        # all-reduce over n=4: wire = 2*(3/4)*payload
        line = ("%ar = f32[100]{0} all-reduce(%x), replica_groups={{0,1,2,3}},"
                " to_apply=%add")
        hlo = ("ENTRY %main (x: f32[100]) -> f32[100] {\n"
               f"  {line}\n"
               "}\n")
        s = hlo_analysis.analyze_module(hlo)
        assert s.coll_wire_bytes == pytest.approx(2 * 0.75 * 400)

    def test_aval_byte_estimates(self):
        """The numpy-side dtype table (shared with the repro.analysis
        vmem-budget pass) agrees with the HLO-side one: a hand-computed
        batched far view — (5, 296, 4, 64) bf16 — prices identically
        through both entry points."""
        a = jax.ShapeDtypeStruct((5, 296, 4, 64), jnp.bfloat16)
        assert hlo_analysis.aval_bytes(a) == 5 * 296 * 4 * 64 * 2
        assert hlo_analysis.dtype_bytes(jnp.bfloat16) == \
            hlo_analysis._DTYPE_BYTES[hlo_analysis.hlo_dtype_name(
                jnp.bfloat16)] == 2
        assert hlo_analysis.hlo_dtype_name(np.dtype("float32")) == "f32"
        assert hlo_analysis.aval_bytes(
            jax.ShapeDtypeStruct((), jnp.int32)) == 4
        with pytest.raises(ValueError):
            hlo_analysis.hlo_dtype_name("not-a-dtype")


class TestPipeline:
    def test_bubble_fraction(self):
        assert pp.bubble_fraction(2, 8) == pytest.approx(1 / 9)
        assert pp.bubble_fraction(1, 8) == 0.0

    def test_single_stage_identity_schedule(self):
        """P=1 pipeline == plain layer application (numerics)."""
        arch = ARCHS["qwen3-1.7b"].reduced()
        params = transformer.init_params(jax.random.key(0), arch)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                    ("pod", "data", "model"))
        loss_fn = pp.make_pp_loss_fn(arch, mesh, n_microbatches=2)
        B, S = 4, 32
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, arch.vocab, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, arch.vocab, (B, S)),
                                  jnp.int32),
        }
        with mesh:
            loss_pp = float(loss_fn(params, batch))
        loss_ref = float(transformer.loss_fn(
            params, batch, arch, remat="none", aux_weight=0.0)[0])
        assert loss_pp == pytest.approx(loss_ref, rel=2e-2)

    def test_split_stages_shapes(self):
        tree = {"w": jnp.zeros((28, 3, 5))}
        out = pp.split_stages(tree, 2)
        assert out["w"].shape == (2, 14, 3, 5)


@pytest.mark.slow
class TestDryRunSubprocess:
    def test_one_cell_end_to_end(self, tmp_path):
        """Full dry-run CLI for one cell in a fresh process (512 devices)."""
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", "qwen3-1.7b", "--shape", "decode_32k",
               "--out", str(tmp_path)]
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=1200,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root"},
                           cwd="/root/repo")
        assert r.returncode == 0, r.stdout + r.stderr
        arts = list(tmp_path.glob("*.json"))
        assert len(arts) == 1
        import json
        art = json.loads(arts[0].read_text())
        assert art["status"] == "ok"
        assert art["hlo"]["flops"] > 0
