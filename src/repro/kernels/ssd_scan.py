"""Pallas kernel: SSD inter-chunk state recurrence (Mamba-2).

The sequential part of the chunked SSD algorithm: carry the (H, P, N) state
across chunks, emitting the state *entering* each chunk.  The parallel
intra-chunk math stays in XLA (it is MXU-friendly einsums); this kernel owns
the serial chain, keeping the state resident in VMEM across the whole scan
instead of round-tripping HBM once per chunk.

Grid: (batch, H / block_h).  VMEM per step: (nc + 2) x block_h x P x N f32
tiles — e.g. nc=16 chunks, block_h=8, P=64, N=128: ~4.5 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_scan_kernel(states_ref, decays_ref, h0_ref, hprev_ref, hfinal_ref, *,
                     n_chunks: int):
    h = h0_ref[0].astype(jnp.float32)                  # (bh, P, N)

    def body(c, h):
        hprev_ref[0, c] = h
        dec = decays_ref[0, c]                          # (bh,)
        st = states_ref[0, c].astype(jnp.float32)       # (bh, P, N)
        return h * dec[:, None, None] + st

    h = jax.lax.fori_loop(0, n_chunks, body, h)
    hfinal_ref[0] = h


def ssd_chunk_scan(states: jax.Array, decays: jax.Array, h0: jax.Array,
                   block_h: int = 8, interpret: bool = False):
    """states: (B,nc,H,P,N); decays: (B,nc,H); h0: (B,H,P,N) — all f32.

    Returns (h_prev (B,nc,H,P,N), h_final (B,H,P,N)).
    """
    B, nc, H, P, N = states.shape
    block_h = min(block_h, H)
    assert H % block_h == 0, (H, block_h)

    kernel = functools.partial(_ssd_scan_kernel, n_chunks=nc)
    h_prev, h_final = pl.pallas_call(
        kernel,
        grid=(B, H // block_h),
        in_specs=[
            pl.BlockSpec((1, nc, block_h, P, N), lambda b, h: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, nc, block_h), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, block_h, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nc, block_h, P, N), lambda b, h: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, block_h, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(states, decays, h0)
    return h_prev, h_final
