import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  This module is the proof that the distribution config
is coherent: for the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh,
``jax.jit(step).lower(...).compile()`` must succeed for every cell, and the
compiled artifact's memory/cost analysis feeds docs/experiments.md §Dry-run and
§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod
  python -m repro.launch.dryrun --list
"""

import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402

from repro.configs.base import shape_applicable          # noqa: E402
from repro.configs.registry import ARCHS, SHAPES         # noqa: E402
from repro.launch import hlo_analysis, serve, train      # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.models import model_zoo, transformer          # noqa: E402
from repro.optim import adamw                            # noqa: E402
from repro.sharding import ctx                           # noqa: E402
from repro.sharding import specs as sh                   # noqa: E402

DEFAULT_OUT = Path("artifacts/dryrun")


def _sds_with(tree_shapes, shardings):
    return jax.tree.map(
        lambda s, sd: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sd),
        tree_shapes, shardings)


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               fsdp: bool = True, remat: str = "full",
               moment_dtype: str = "f32", seq_shard: bool = False,
               group_size: int = 1024, pad_heads: int = 0,
               grad_dtype: str = "f32", shard_logits: bool = False,
               sparse_kv_pages: int = 0, moe_impl: str = "einsum",
               moe_group: int = 1024, serve_dtype: str = "f32",
               params_dtype: str = "f32"):
    """Lower + compile one cell; returns the artifact dict.

    Perf-iteration knobs (docs/experiments.md §Perf):
      pad_heads: pad Q heads to N so they divide the model axis (TP for
        awkward head counts; dummy heads are function-preserving).
      grad_dtype: 'bf16' reduces gradients in bf16 (half the DP wire bytes).
      shard_logits: keep output logits vocab-sharded instead of replicated.
      sparse_kv_pages: decode attends near-tier pages + recent window only
        (the TL-DRAM sparse serving mode; >0 enables with that many pages).
    """
    import dataclasses as _dc

    from repro.models import moe as moe_lib

    arch = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    if pad_heads:
        arch = _dc.replace(arch, n_heads=pad_heads,
                           head_dim=arch.resolved_head_dim)
    moe_lib.DEFAULT_IMPL = moe_impl
    moe_lib.DEFAULT_GROUP_SIZE = moe_group
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    cfg = train.TrainConfig(
        remat=remat, grad_dtype=grad_dtype,
        adamw=adamw.AdamWConfig(moment_dtype=moment_dtype))

    p_dtype = jnp.float32
    if serve_dtype == "bf16" and shape.kind != "train":
        p_dtype = jnp.bfloat16
    if params_dtype == "bf16":
        # bf16 parameters end-to-end (f32 lives only in optimizer moments):
        # FSDP all-gathers and gradient reductions move bf16 on the wire.
        p_dtype = jnp.bfloat16
    param_shapes = jax.eval_shape(
        lambda: transformer.init_params(jax.random.key(0), arch,
                                        dtype=p_dtype))
    pspecs = sh.param_specs(param_shapes, arch, mesh, fsdp=fsdp)
    pshard = sh.to_named(pspecs, mesh)
    params_sds = _sds_with(param_shapes, pshard)

    batch_shapes = model_zoo.input_specs(arch, shape)
    bspecs = sh.batch_specs(batch_shapes, arch, shape, mesh,
                            seq_shard=seq_shard)
    bshard = sh.to_named(bspecs, mesh)
    batch_sds = _sds_with(batch_shapes, bshard)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(
            lambda: adamw.init(param_shapes, cfg.adamw))
        ospecs = sh.moment_specs(pspecs, opt_shapes, mesh, fsdp=fsdp)
        oshard = sh.to_named(ospecs, mesh)
        opt_sds = _sds_with(opt_shapes, oshard)
        step_fn = train.make_train_step(arch, cfg)
        with mesh, ctx.activation_sharding(mesh, seq_shard=seq_shard):
            lowered = jax.jit(
                step_fn,
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            ).lower(params_sds, opt_sds, batch_sds)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        step_fn = serve.make_prefill_step(arch, max_len=shape.seq_len)
        cache_shapes = jax.eval_shape(
            lambda: transformer.init_cache(arch, shape.global_batch,
                                           shape.seq_len))
        cspecs = sh.cache_specs(cache_shapes, arch, mesh)
        cshard = sh.to_named(cspecs, mesh)
        with mesh, ctx.activation_sharding(mesh, seq_shard=seq_shard):
            lowered = jax.jit(
                step_fn, out_shardings=(None, cshard),
            ).lower(params_sds, batch_sds)
            compiled = lowered.compile()
    else:  # decode
        if sparse_kv_pages and arch.n_heads and not arch.sliding_window:
            step_fn = serve.make_sparse_tiered_decode_step(
                arch, near_pages=sparse_kv_pages)
            extras = jax.eval_shape(
                lambda: serve.sparse_cache_extras(arch, shape.global_batch,
                                                  shape.seq_len,
                                                  sparse_kv_pages, 128))
        else:
            step_fn = serve.make_decode_step(arch)
            extras = {}
        cache_shapes = jax.eval_shape(
            lambda: transformer.init_cache(arch, shape.global_batch,
                                           shape.seq_len))
        cache_shapes = {**cache_shapes, **extras}
        cspecs = sh.cache_specs(cache_shapes, arch, mesh)
        cshard = sh.to_named(cspecs, mesh)
        cache_sds = _sds_with(cache_shapes, cshard)
        with mesh, ctx.activation_sharding(mesh, seq_shard=seq_shard):
            lowered = jax.jit(
                step_fn, out_shardings=(None, cshard), donate_argnums=(1,),
            ).lower(params_sds, cache_sds, batch_sds)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # jax 0.4.x returns [dict]; >=0.5 a dict
        cost = cost[0] if cost else {}
    hlo = hlo_analysis.analyze_module(compiled.as_text())

    art = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": mesh.devices.size,
        "status": "ok",
        "compile_seconds": round(compile_s, 1),
        "config": {"fsdp": fsdp, "remat": remat, "moe_impl": moe_impl,
                   "moment_dtype": moment_dtype, "seq_shard": seq_shard,
                   "pad_heads": pad_heads, "grad_dtype": grad_dtype,
                   "sparse_kv_pages": sparse_kv_pages,
                   "serve_dtype": serve_dtype,
                   "params_dtype": params_dtype},
        "params": arch.param_count(),
        "active_params": arch.active_param_count(),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        # xla_cost: per-device, but while-loop bodies counted ONCE (see
        # hlo_analysis docstring) — kept for reference only.
        "xla_cost": {"flops": cost.get("flops", 0.0),
                     "bytes_accessed": cost.get("bytes accessed", 0.0)},
        # hlo: loop-aware per-device totals used by the roofline.
        "hlo": hlo.as_dict(),
    }
    return art


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--moment-dtype", default="f32")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--grad-dtype", default="f32")
    ap.add_argument("--sparse-kv-pages", type=int, default=0)
    ap.add_argument("--moe-impl", default="einsum")
    ap.add_argument("--moe-group", type=int, default=1024)
    ap.add_argument("--serve-dtype", default="f32")
    ap.add_argument("--params-dtype", default="f32")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    if args.list:
        for a in archs:
            for s in shapes:
                ok, why = shape_applicable(ARCHS[a], SHAPES[s])
                print(f"{a:26s} {s:12s} {'run' if ok else 'SKIP: ' + why}")
        return 0

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for a in archs:
        for s in shapes:
            for mp in meshes:
                tag = f"{a}__{s}__{'multi' if mp else 'single'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = out_dir / f"{tag}.json"
                try:
                    art = lower_cell(a, s, mp, fsdp=bool(args.fsdp),
                                     remat=args.remat,
                                     moment_dtype=args.moment_dtype,
                                     seq_shard=args.seq_shard,
                                     pad_heads=args.pad_heads,
                                     grad_dtype=args.grad_dtype,
                                     sparse_kv_pages=args.sparse_kv_pages,
                                     moe_impl=args.moe_impl,
                                     moe_group=args.moe_group,
                                     serve_dtype=args.serve_dtype,
                                     params_dtype=args.params_dtype)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    art = {"arch": a, "shape": s,
                           "mesh": "multi" if mp else "single",
                           "status": "failed", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                path.write_text(json.dumps(art, indent=1))
                status = art["status"]
                extra = (f"compile={art.get('compile_seconds')}s"
                         if status == "ok" else art.get("reason",
                                                        art.get("error", "")))
                print(f"[dryrun] {tag}: {status} {extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
