"""Stream-replay parity: the vectorized `repro.tier` engines vs the object
oracle (`repro.tier.reference`), for ALL FOUR policies, plus the simulator
end-to-end check that per-policy hit rates are unchanged by the refactor.
"""

import numpy as np
import pytest

from repro.tier import TierCosts, TierEngine
from repro.tier import jax_engine, reference, rules

COSTS = TierCosts(near_cost=23.4, far_cost=65.8, migrate_cost=69.8)
ALL_POLICIES = ("SC", "WMC", "BBC", "STATIC")


def _zipf_stream(n, rows, alpha=1.4, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, rows + 1)
    p = ranks ** -alpha
    p /= p.sum()
    return (rng.choice(rows, size=n, p=p), rng.random(n) < 0.3,
            rng.random(n) < 0.5)


def _preload_both(stream, N, pol, st, eng):
    counts = np.bincount(stream, minlength=N).astype(float)
    first = np.full(N, np.iinfo(np.int64).max, np.int64)
    np.minimum.at(first, stream, np.arange(len(stream)))
    # dict insertion order == first occurrence, like the simulator's profiler
    profile = {}
    for r in stream:
        profile.setdefault(int(r), 0)
        profile[int(r)] += 1
    pol.preload(st, profile)
    eng.preload(counts[None, :], first[None, :])


class TestPerAccessParity:
    """Replays one access stream through the object oracle and the NumPy
    engine in lock-step, asserting *identical decisions at every access*
    (promote flag, victim row, victim dirtiness) and identical final state.
    The replay mirrors the DRAM controller's ordering: on_access ->
    periodic decay -> decide."""

    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_decisions_identical(self, policy):
        N, C, period = 64, 8, 16
        stream, writes, idles = _zipf_stream(2500, N, seed=3)
        pol = reference.make_policy(policy, COSTS)
        st = reference.CacheState(capacity=C)
        eng = TierEngine(policy, COSTS, groups=1, rows=N, capacity=C,
                         decay_period=period)
        if policy == "STATIC":
            _preload_both(stream, N, pol, st, eng)

        promotions = 0
        for i, (row, w, idle) in enumerate(zip(stream, writes, idles)):
            row, now = int(row), float(i) * 10.0 + 5.0
            in_near = st.hit(row)
            assert in_near == eng.hit(0, row), f"hit mismatch at access {i}"
            pol.on_access(st, row, now, bool(w), in_near, activated=True)
            eng.on_access(0, row, now, bool(w), in_near, activated=True)
            if (i + 1) % period == 0:     # engine decayed inside on_access
                pol.decay_scores(st)
            if in_near:
                continue
            d_ref = pol.decide(st, row, now, bank_idle=bool(idle))
            d_vec = eng.decide(0, row, now, bank_idle=bool(idle))
            assert d_ref.promote == d_vec.promote, f"access {i}"
            if d_ref.promote:
                promotions += 1
                want_victim = -1 if d_ref.victim_row is None else d_ref.victim_row
                assert want_victim == d_vec.victim_row, f"access {i}"
                assert d_ref.victim_dirty == d_vec.victim_dirty, f"access {i}"
                pol.apply_promotion(st, row, d_ref)
                eng.apply(0, row, d_vec)

        cached_vec = set(eng.row_of_slot[0][eng.row_of_slot[0] >= 0].tolist())
        assert cached_vec == set(st.lookup)
        assert set(np.nonzero(eng.dirty[0])[0].tolist()) == set(st.dirty)
        if policy in ("SC", "WMC", "BBC"):
            assert promotions > 0, "stream must exercise migrations"
        else:
            assert promotions == 0

    def test_groups_are_independent(self):
        """One batched engine over G groups == G single-group engines."""
        N, C, G = 32, 4, 3
        stream, writes, _ = _zipf_stream(900, N, seed=7)
        groups = np.random.default_rng(1).integers(0, G, size=900)
        batched = TierEngine("BBC", COSTS, groups=G, rows=N, capacity=C)
        singles = [TierEngine("BBC", COSTS, groups=1, rows=N, capacity=C)
                   for _ in range(G)]
        for i, (g, row, w) in enumerate(zip(groups, stream, writes)):
            g, row, now = int(g), int(row), float(i)
            for eng, gi in ((batched, g), (singles[g], 0)):
                in_near = eng.hit(gi, row)
                eng.on_access(gi, row, now, bool(w), in_near)
                if not in_near:
                    d = eng.decide(gi, row, now, bank_idle=True)
                    if d.promote:
                        eng.apply(gi, row, d)
        for g in range(G):
            np.testing.assert_array_equal(batched.row_of_slot[g],
                                          singles[g].row_of_slot[0])
            np.testing.assert_array_equal(batched.slot_of_row[g],
                                          singles[g].slot_of_row[0])


class TestIntervalEngineParity:
    """The jittable interval engine against the object oracle on shared
    Zipfian streams (interval-batched, like the TPU runtime drives it)."""

    def _drive_object(self, policy, stream, N, C, period=16, decay=0.9):
        pol = reference.make_policy(policy, COSTS)
        pol.decay = decay
        if policy == "BBC":
            pol.min_score = 2.0
        st = reference.CacheState(capacity=C)
        for i, row in enumerate(stream):
            in_near = st.hit(int(row))
            pol.on_access(st, int(row), float(i), False, in_near)
            if not in_near:
                d = pol.decide(st, int(row), float(i), bank_idle=True)
                if d.promote:
                    pol.apply_promotion(st, int(row), d)
            if i % period == period - 1:
                pol.decay_scores(st)
        return set(st.lookup)

    def _drive_interval(self, policy, stream, N, C, period=16, idle=True):
        import jax.numpy as jnp
        costs = TierCosts(near_cost=23.4, far_cost=65.8, migrate_cost=69.8,
                          decay=0.9)
        scores = jnp.zeros((N,), jnp.float32)
        last_use = jnp.zeros((N,), jnp.float32)
        slot_of = -jnp.ones((N,), jnp.int32)
        row_of = -jnp.ones((C,), jnp.int32)
        for start in range(0, len(stream), period):
            batch = stream[start:start + period]
            counts = np.bincount(batch, minlength=N).astype(np.float32)
            scores = jax_engine.ema_update(scores, jnp.asarray(counts), costs)
            last_use = jnp.where(jnp.asarray(counts) > 0,
                                 float(start // period), last_use)
            rows, slots, valid = jax_engine.plan_promotions(
                scores, slot_of, row_of, costs, max_promotions=2,
                policy=policy, last_use=last_use,
                accessed=jnp.asarray(counts) > 0, idle=idle)
            slot_of, row_of = jax_engine.apply_promotions(
                slot_of, row_of, rows, slots, valid)
        cached = np.asarray(row_of)
        return set(cached[cached >= 0].tolist())

    @pytest.mark.parametrize("policy", ["SC", "WMC", "BBC"])
    def test_interval_engine_captures_zipf_head(self, policy):
        N, C = 32, 4
        stream, _, _ = _zipf_stream(400, N, alpha=1.5, seed=0)
        obj = self._drive_object(policy, stream, N, C)
        vec = self._drive_interval(policy, stream, N, C)
        # Interval batching can't match per-access decisions step for step;
        # both must cache the hottest row and mostly agree on the head.
        assert 0 in obj and 0 in vec
        assert len(vec & obj) / max(len(obj), 1) >= 0.5, (vec, obj)

    def test_wmc_idle_gate_blocks_promotions(self):
        N, C = 32, 4
        stream, _, _ = _zipf_stream(400, N, alpha=1.5, seed=0)
        assert self._drive_interval("WMC", stream, N, C, idle=False) == set()
        assert (self._drive_interval("WMC", stream, N, C, idle=True)
                == self._drive_interval("SC", stream, N, C))

    def test_static_preload_matches_oracle(self):
        import jax.numpy as jnp
        N, C = 48, 6
        stream, _, _ = _zipf_stream(300, N, alpha=1.3, seed=2)
        counts = np.bincount(stream, minlength=N).astype(np.float32)
        pol = reference.make_policy("STATIC", COSTS)
        st = reference.CacheState(capacity=C)
        pol.preload(st, {r: int(counts[r]) for r in np.argsort(-counts)[:2 * C]})
        slot_of, row_of = jax_engine.preload_static(jnp.asarray(counts), C)
        cached = np.asarray(row_of)
        assert set(cached[cached >= 0].tolist()) == set(st.lookup)
        # mapping arrays are mutually consistent
        so = np.asarray(slot_of)
        for slot, row in enumerate(cached):
            if row >= 0:
                assert so[row] == slot

    def test_shared_rules_numpy_equals_jax(self):
        """The decision core gives bit-identical plans under numpy and jnp."""
        import jax.numpy as jnp
        N, C = 24, 5
        rng = np.random.default_rng(4)
        scores = rng.gamma(2.0, 2.0, N).astype(np.float32)
        last_use = rng.permutation(N).astype(np.float32)
        slot_of = -np.ones(N, np.int32)
        row_of = -np.ones(C, np.int32)
        for slot, row in enumerate(rng.choice(N, C - 1, replace=False)):
            slot_of[row] = slot
            row_of[slot] = row
        for policy in ALL_POLICIES:
            r_np = rules.plan_promotions_xp(
                np, policy, scores, slot_of, row_of, COSTS, 3,
                last_use=last_use)
            r_jx = rules.plan_promotions_xp(
                jnp, policy, jnp.asarray(scores), jnp.asarray(slot_of),
                jnp.asarray(row_of), COSTS, 3, last_use=jnp.asarray(last_use))
            for a, b in zip(r_np, r_jx):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=policy)


class TestSimulatorHitRatesUnchanged:
    """End-to-end: per-policy near-segment hit rates (and migration /
    write-back counts) through the vectorized engine are IDENTICAL to the
    seed's per-subarray dict implementation (values recorded at the seed
    commit, 4000 requests per run)."""

    GOLDEN = {
        # (policy, mix, seed): (near_hit_rate, migrations, writebacks)
        ("SC", "hot", 1): (0.959000, 164, 0),
        ("SC", "mixed", 5): (0.933750, 265, 0),
        ("SC", "stream", 9): (0.707250, 1171, 251),
        ("WMC", "hot", 1): (0.949000, 142, 0),
        ("WMC", "mixed", 5): (0.912750, 238, 0),
        ("WMC", "stream", 9): (0.489250, 998, 132),
        ("BBC", "hot", 1): (0.890750, 51, 0),
        ("BBC", "mixed", 5): (0.819750, 105, 0),
        ("BBC", "stream", 9): (0.035250, 81, 0),
        ("STATIC", "hot", 1): (1.000000, 0, 0),
        ("STATIC", "mixed", 5): (1.000000, 0, 0),
        ("STATIC", "stream", 9): (0.744500, 0, 0),
    }

    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_hit_rates_match_seed(self, policy):
        from repro.core import simulator as S, traces as T
        for (pol, mix, seed), (hit, migr, wb) in self.GOLDEN.items():
            if pol != policy:
                continue
            tr = T.make_mix((mix,), n_requests=4000, seed=seed)
            tl = S.simulate(S.SimConfig(
                device=S.DeviceConfig(kind="tldram", policy=policy)), tr)
            assert tl.near_hit_rate == pytest.approx(hit, abs=1e-9), (mix, seed)
            assert tl.migrations == migr, (mix, seed)
            assert tl.writebacks == wb, (mix, seed)
