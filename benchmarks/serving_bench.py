"""Serving-engine scenario suite (the serving twin of the paper's Fig 8).

Four arrival scenarios x four tier policies through the continuous-batching
engine (`repro.serve`), reporting per cell:

  tokens/s (wall)       : aggregate decode throughput, post-compile.
  tokens/kcost          : modeled-byte-cost throughput (near pages streamed,
                          far pages gather-derated, IST billed — TierCosts).
  near-tier hit mass    : attention mass served by the near tier (the
                          paper's near-segment hit rate analogue).
  p50 / p99 latency     : modeled per-token latency (inter-token gaps;
                          first token includes queueing + prefill).

Plus the continuous-vs-sequential acceptance cell: on the steady-Zipfian
scenario the engine must sustain >= 2x the aggregate tokens/s of serving
the same trace with single-sequence ``greedy_generate`` calls, with every
emitted token identical to that reference.

  PYTHONPATH=src python -m benchmarks.serving_bench
"""

from __future__ import annotations

import jax

from repro.configs.registry import ARCHS
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer
from repro.serve import (ServingConfig, ServingEngine, ServingReport,
                         sequential_baseline)
from repro.serve.trace import SCENARIOS

POLICIES = ("SC", "WMC", "BBC", "STATIC")


def _setup(arch_name="qwen3-1.7b", seed=0):
    arch = ARCHS[arch_name].reduced()
    params = transformer.init_params(jax.random.key(seed), arch)
    return arch, params


def _config(policy: str, n_slots=6, max_len=128, page=16, near_pages=2,
            interval=4) -> ServingConfig:
    tier = TieredKVConfig(page=page, near_pages=near_pages,
                          interval=interval, policy=policy)
    return ServingConfig(n_slots=n_slots, max_len=max_len,
                         prefill_bucket=16, tier=tier)


def _traces(vocab: int):
    return {
        "steady_zipfian": SCENARIOS["steady_zipfian"](
            vocab, n_requests=12, prompt_len=24, max_new_tokens=16, gap=1),
        "bursty": SCENARIOS["bursty"](
            vocab, n_requests=12, prompt_len=24, max_new_tokens=16,
            burst=4, burst_gap=16),
        "long_context_stragglers": SCENARIOS["long_context_stragglers"](
            vocab, n_requests=10, prompt_len=16, max_new_tokens=12,
            straggler_every=4, long_factor=4),
        "shifting_hotspot": SCENARIOS["shifting_hotspot"](
            vocab, n_requests=12, prompt_len=24, max_new_tokens=16, gap=1),
    }


def bench_scenarios(arch_name="qwen3-1.7b", policies=POLICIES):
    """All scenarios x all policies.  One engine per policy (the jitted
    decode/plan programs are shared across its four scenario runs)."""
    arch, params = _setup(arch_name)
    traces = _traces(arch.vocab)
    rows = []
    for policy in policies:
        eng = ServingEngine(params, arch, _config(policy))
        for name, trace in traces.items():
            eng.run(trace, "warmup")    # compile this cell's shapes
                                        # (prefill buckets differ by
                                        # scenario) outside the timed run
            rep = eng.run(trace, name)
            rows.append(rep.summary_row())
    return rows


def bench_continuous_vs_sequential(arch_name="qwen3-1.7b", policy="BBC"):
    """Acceptance cell: >= 2x sequential greedy_generate on steady Zipfian,
    token-identical outputs."""
    arch, params = _setup(arch_name)
    cfg = _config(policy)
    trace = _traces(arch.vocab)["steady_zipfian"]
    eng = ServingEngine(params, arch, cfg)
    eng.run(trace, "warmup")
    rep = eng.run(trace, "steady_zipfian")
    sequential_baseline(params, arch, trace, cfg)       # warm the jits
    base = sequential_baseline(params, arch, trace, cfg,
                               "steady_zipfian")
    mismatches = sum(rep.outputs[r] != base.outputs[r] for r in rep.outputs)
    speedup = rep.tokens_per_s_wall / base.tokens_per_s_wall
    assert mismatches == 0, \
        f"{mismatches} sequences diverge from greedy_generate"
    assert speedup >= 2.0, \
        f"continuous batching only {speedup:.2f}x sequential"
    return [
        ("continuous_vs_sequential", "engine_tok_s",
         round(rep.tokens_per_s_wall, 1)),
        ("continuous_vs_sequential", "sequential_tok_s",
         round(base.tokens_per_s_wall, 1)),
        ("continuous_vs_sequential", "speedup", round(speedup, 2)),
        ("continuous_vs_sequential", "outputs_identical", mismatches == 0),
    ]


def run_all():
    rows = [ServingReport.HEADER] + bench_scenarios()
    rows += bench_continuous_vs_sequential()
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    run_all()
