"""Tiered KV cache: the TL-DRAM near/far substrate applied to decode serving.

Mapping (docs/design.md §2b):

  far tier   : the full KV cache (master copy; new tokens append here) —
               the long-bitline segment.  Gather-addressed => slow path.
  near tier  : a small contiguous buffer of *copies* of hot KV pages —
               the near segment.  Dense, VMEM-streamable by the Pallas
               kernel (`kernels.tiered_attention`) => fast path.
  IST        : promotions/evictions are pure on-device page copies
               (`dynamic_update_slice`) — no collectives, no host round-trip,
               mirroring the paper's channel-free inter-segment transfer
               (asserted by tests: migration HLO contains no collective ops).
  policy     : every `interval` decode steps, a scoring pass measures per-page
               attention mass with the current queries (the paper's
               interval-sampled activation counts), EMA-updates page scores,
               and runs the shared vectorized engine (`repro.tier.jax_engine`)
               under any of the four paper policies — SC, WMC, BBC (default)
               or STATIC (profile preload via `preload_static`).

KV pages are immutable once written, so evictions are always clean (the
paper's dirty-eviction write-back IST never triggers for this workload — a
fact we note rather than hide).

Two far-tier layouts share the policy machinery:

  monolithic : the original per-slot (B, T, Hkv, hd) buffer — every
               sequence owns private copies of its pages (top half of this
               module).
  paged      : a refcounted shared page pool with per-slot page tables and
               a GLOBAL near tier scored by aggregate attention mass
               (docs/design.md §2d; the `paged_*` functions + `PagePool`
               below).  Shared prompt prefixes are stored once and
               promoted once for all tenants — the serving engine's
               default since PR 3, fed by `repro.serve.prefix`.

Correctness invariant (tested): near+far partitioned attention with LSE merge
is *exactly* standard attention over the full cache — in both layouts
(tests/test_read_path.py, tests/test_paged_read_path.py).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.tier import TierCosts, ema_update
from repro.tier.jax_engine import (apply_promotions, plan_promotions,
                                   preload_static)
from repro.kernels import ops, ref

# Cost model (napkin math, documented in docs/experiments.md): far pages are
# gather-addressed — effective HBM bandwidth for 2KB-grain gathers is ~1/4 of
# streaming bandwidth on TPU-class memory systems; near pages stream at full
# bandwidth.  Migration copies a page (read + write) at streaming bandwidth.
DEFAULT_COSTS = TierCosts(near_cost=1.0, far_cost=4.0, migrate_cost=8.0,
                          hysteresis=2.0, min_score=2.0, decay=0.9)


@dataclass
class TieredKVConfig:
    page: int = 128               # tokens per page
    near_pages: int = 8           # near-tier capacity: pages per sequence
                                  # (monolithic mode) or total pages shared
                                  # by the whole pool (paged mode)
    interval: int = 16            # decode steps between planning passes
    max_promotions: int = 2       # migrations per planning pass
    policy: str = "BBC"           # SC | WMC | BBC | STATIC
    costs: TierCosts = DEFAULT_COSTS
    gather_kernel: bool = False   # paged mode: materialize the far view with
                                  # the Pallas paged-gather kernel instead of
                                  # an XLA take (parity pinned by tests)
    fused_kernel: bool = False    # paged mode: read through the fused
                                  # page-table-walking Pallas kernel
                                  # (kernels.paged_attention) — NO far-view
                                  # materialization; far bytes touched per
                                  # step = live non-promoted page rows only.
                                  # The dense path stays the oracle.
    mesh: object = None           # jax.sharding.Mesh: shard the pool/near
                                  # buffers by KV head over the 'model' axis
                                  # (shard_map around every Pallas read;
                                  # scatter/slice paths partition under
                                  # GSPMD).  Falls back to replication when
                                  # Hkv does not divide the axis
                                  # (sharding.specs.kv_shard_count).  None:
                                  # single-device (the default everywhere).


def init_tiered_cache(k_cache: jax.Array, v_cache: jax.Array,
                      cfg: TieredKVConfig) -> dict:
    """Wrap an existing (B, T, Hkv, hd) far cache with near-tier state."""
    B, T, Hkv, hd = k_cache.shape
    assert T % cfg.page == 0, f"cache length {T} must be a page multiple"
    n_pages = T // cfg.page
    C = cfg.near_pages
    return {
        "far_k": k_cache, "far_v": v_cache,
        "near_k": jnp.zeros((B, C * cfg.page, Hkv, hd), k_cache.dtype),
        "near_v": jnp.zeros((B, C * cfg.page, Hkv, hd), v_cache.dtype),
        "slot_of_page": -jnp.ones((B, n_pages), jnp.int32),
        "page_of_slot": -jnp.ones((B, C), jnp.int32),
        "scores": jnp.zeros((B, n_pages), jnp.float32),
        # SC/WMC LRU stamps: planning-interval index of each page's last
        # nonzero attention mass (BBC/STATIC ignore them).
        "last_use": jnp.zeros((B, n_pages), jnp.float32),
        "step": jnp.zeros((), jnp.int32),
        "migrations": jnp.zeros((), jnp.int32),
    }


def _pos_vec(pos, B: int) -> jax.Array:
    """Normalize a decode position to a per-sequence (B,) vector.

    Every read-path entry point accepts either the legacy scalar (one
    position shared by the whole batch) or a ragged per-slot vector (the
    continuous-batching serving engine's slot pool)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))
    return pos


def near_token_count(cache: dict, cfg: TieredKVConfig) -> jax.Array:
    """(B,) live near-tier token count.  Occupied slots always form a
    prefix (pinned by tests/test_read_path.py), so count * page is the
    exact live region the kernel streams."""
    occupied = (cache["page_of_slot"] >= 0)
    return occupied.sum(axis=1).astype(jnp.int32) * cfg.page


def reset_sequences(cache: dict, rows: jax.Array) -> dict:
    """Clear tier state for retired slots (rows: (B,) bool mask).

    The far/near K,V buffers are left untouched — a cleared mapping makes
    the near copies unreachable (near_len excludes them) and the next
    prefill overwrites the far rows; only the policy state must not leak
    into the slot's next tenant."""
    cache = dict(cache)
    r = rows[:, None]
    cache["slot_of_page"] = jnp.where(r, -1, cache["slot_of_page"])
    cache["page_of_slot"] = jnp.where(r, -1, cache["page_of_slot"])
    cache["scores"] = jnp.where(r, 0.0, cache["scores"])
    cache["last_use"] = jnp.where(r, 0.0, cache["last_use"])
    return cache


def append_token(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array) -> dict:
    """Append one token's K/V to the far tier (master copy).

    pos: scalar position, or a (B,) vector for ragged per-slot appends."""
    cache = dict(cache)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        b_idx = jnp.arange(k_new.shape[0])
        cache["far_k"] = cache["far_k"].at[b_idx, pos].set(k_new[:, 0])
        cache["far_v"] = cache["far_v"].at[b_idx, pos].set(v_new[:, 0])
    else:
        cache["far_k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["far_k"], k_new, pos, 1)
        cache["far_v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["far_v"], v_new, pos, 1)
    return cache


def tiered_attention(cache: dict, q: jax.Array, pos: jax.Array,
                     cfg: TieredKVConfig) -> jax.Array:
    """Two-tier decode attention.  q: (B,H,hd); pos: scalar current
    position, or a (B,) vector of ragged per-slot positions.

    Near path: Pallas kernel over the contiguous near buffer.
    Far path: XLA attention over the far cache, with promoted pages masked
    out (they are served from the near tier) and positions >= pos masked.
    """
    B, H, hd = q.shape
    T = cache["far_k"].shape[1]
    page = cfg.page
    pos = _pos_vec(pos, B)

    # Near tier: occupied slots always form a prefix (promotions fill empty
    # slots in index order and evictions replace in place), so the live
    # region is simply count * page.
    near_len = near_token_count(cache, cfg)

    out_n, m_n, l_n = _near_stats(q, cache, near_len, cfg)

    # far mask: slot < pos and the slot's page is not promoted
    slots = jnp.arange(T)
    page_of_slot_idx = slots // page                        # (T,)
    promoted = cache["slot_of_page"][:, page_of_slot_idx] >= 0   # (B,T)
    live = (slots[None, :] < pos[:, None]) & ~promoted
    out_f, m_f, l_f = _far_stats(q, cache["far_k"], cache["far_v"], live)

    return ref.merge_attention_stats([(out_n, m_n, l_n), (out_f, m_f, l_f)])


def _near_stats(q, cache, near_len, cfg: TieredKVConfig):
    from repro.kernels.tiered_attention import near_decode_attention
    interpret = jax.default_backend() == "cpu"
    return near_decode_attention(q, cache["near_k"], cache["near_v"],
                                 near_len, interpret=interpret)


def _far_stats(q, k, v, live_mask):
    """XLA far-tier attention returning online-softmax stats.
    q: (B,H,hd); k/v: (B,T,Hkv,hd); live_mask: (B,T) bool."""
    B, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qh = q.reshape(B, Hkv, g, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    s = jnp.where(live_mask[:, None, None, :], s, ref.NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None]) * live_mask[:, None, None, :]
    l = p.sum(axis=-1)
    # f32 p@v accumulation, matching the Pallas kernels and the dense
    # decode path — cross-path noise stays at reduction-order level
    out = jnp.einsum("bkgt,btkd->bkgd", p, v,
                     preferred_element_type=jnp.float32)
    return (out.reshape(B, H, hd),
            m.reshape(B, H), l.reshape(B, H))


def _token_masses(q: jax.Array, k: jax.Array, live: jax.Array) -> jax.Array:
    """(B, T) per-token attention mass, summed over heads (the caller
    divides by H after its page-sum).  live: (B, T) bool; dead tokens get
    exactly zero mass.  Shared by the monolithic and paged scoring passes
    so both modes stay decision-identical by construction."""
    B, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qh = q.reshape(B, Hkv, g, hd) * hd ** -0.5
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k).astype(jnp.float32)
    lv = live[:, None, None, :]
    s = jnp.where(lv, s, ref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(lv, p, 0.0)
    return p.sum(axis=(1, 2))                                # (B,T)


def page_masses(q: jax.Array, cache: dict, pos: jax.Array,
                cfg: TieredKVConfig) -> jax.Array:
    """Scoring pass: per-page attention mass with the current queries —
    the interval-sampled activation counts of the paper's BBC.

    Returns (B, n_pages) f32 normalized masses over the *whole* cache
    (near-resident pages included, so retention scores stay fresh).
    ``pos`` may be a scalar or a ragged (B,) vector."""
    B, H, _ = q.shape
    k = cache["far_k"]
    T = k.shape[1]
    live = jnp.arange(T)[None, :] < _pos_vec(pos, B)[:, None]
    mass = _token_masses(q, k, live)
    n_pages = T // cfg.page
    return mass.reshape(B, n_pages, cfg.page).sum(-1) / max(H, 1)


def _copy_pool_pages(near_k, near_v, pool_k, pool_v, pages, slots, valid,
                     page: int):
    """IST analogue: copy up to K pages of a (P, page, ...) page array into
    (C*page, ...) near buffers (pure on-device dynamic slices; invalid plan
    entries are dropped).  Serves both tier layouts — the monolithic far
    buffer reshapes to page-major via ``_copy_pages``."""

    def copy_page(i, bufs):
        nk, nv = bufs
        src = jnp.where(valid[i], pages[i], 0)
        dst = jnp.where(valid[i], slots[i], 0) * page
        page_k = jax.lax.dynamic_slice_in_dim(pool_k, src, 1, 0)[0]
        page_v = jax.lax.dynamic_slice_in_dim(pool_v, src, 1, 0)[0]
        nk_new = jax.lax.dynamic_update_slice_in_dim(nk, page_k, dst, 0)
        nv_new = jax.lax.dynamic_update_slice_in_dim(nv, page_v, dst, 0)
        nk = jnp.where(valid[i], nk_new, nk)
        nv = jnp.where(valid[i], nv_new, nv)
        return nk, nv

    return jax.lax.fori_loop(0, pages.shape[0], copy_page, (near_k, near_v))


def _copy_pages(near_k, near_v, far_k, far_v, rows, slots, valid, page: int):
    """Monolithic-layout wrapper: view the (T, ...) far buffer page-major
    and defer to the shared page copier."""
    return _copy_pool_pages(
        near_k, near_v,
        far_k.reshape(far_k.shape[0] // page, page, *far_k.shape[1:]),
        far_v.reshape(far_v.shape[0] // page, page, *far_v.shape[1:]),
        rows, slots, valid, page)


def plan_and_migrate(cache: dict, q: jax.Array, pos: jax.Array,
                     cfg: TieredKVConfig, idle=True,
                     masses: jax.Array | None = None) -> dict:
    """One planning interval: score -> plan -> migrate (vectorized over
    batch) under ``cfg.policy``.

    Only pages that are completely written (page_end <= pos) are candidates.
    Migration is a pure on-device copy — the IST analogue.  ``idle`` is the
    WMC gate: pass False (or a traced bool) when the serving step has no
    spare migration budget; SC/BBC ignore it, STATIC never migrates.
    ``pos`` may be a scalar or a ragged (B,) vector (the serving engine's
    slot pool — each slot's complete-page frontier is its own).
    ``masses``: optionally pass a precomputed ``page_masses(q, ...)`` result
    (callers that also need the masses for metrics avoid scoring twice).
    """
    if cfg.policy.upper() == "STATIC":
        return cache   # OS-exposed mechanism: no runtime migration, and no
                       # point paying the scoring pass for dead state
    cache = dict(cache)
    if masses is None:
        masses = page_masses(q, cache, pos, cfg)
    n_pages = masses.shape[1]
    pos_b = _pos_vec(pos, masses.shape[0])
    complete = (jnp.arange(n_pages)[None, :] + 1) * cfg.page <= pos_b[:, None]
    masses = jnp.where(complete, masses, 0.0)
    # EMA in "activations per interval" units: scale mass to a count-like
    # magnitude so TierCosts thresholds behave like the DRAM policy's.
    acts = masses * cfg.interval
    cache["scores"] = ema_update(cache["scores"], acts, cfg.costs)
    cache["last_use"] = jnp.where(acts > 0, cache["step"].astype(jnp.float32),
                                  cache["last_use"])
    cache["step"] = cache["step"] + 1

    # SC/WMC cache what received attention mass *this interval*; BBC keeps
    # its sustained-reuse eligibility over the full EMA score population.
    sc_like = cfg.policy.upper() in ("SC", "WMC")

    def per_seq(acts_row, scores, last_use, slot_of_page, page_of_slot,
                near_k, near_v, far_k, far_v):
        rows, slots, valid = plan_promotions(
            scores, slot_of_page, page_of_slot, cfg.costs,
            cfg.max_promotions, policy=cfg.policy, last_use=last_use,
            accessed=(acts_row > 0) if sc_like else None, idle=idle)
        slot_of_page, page_of_slot = apply_promotions(
            slot_of_page, page_of_slot, rows, slots, valid)
        near_k, near_v = _copy_pages(near_k, near_v, far_k, far_v, rows,
                                     slots, valid, cfg.page)
        return slot_of_page, page_of_slot, near_k, near_v, valid.sum()

    (cache["slot_of_page"], cache["page_of_slot"], cache["near_k"],
     cache["near_v"], n_migr) = jax.vmap(per_seq)(
        acts, cache["scores"], cache["last_use"], cache["slot_of_page"],
        cache["page_of_slot"], cache["near_k"], cache["near_v"],
        cache["far_k"], cache["far_v"])
    cache["migrations"] = cache["migrations"] + n_migr.sum().astype(jnp.int32)
    return cache


def preload_static_kv(cache: dict, profile_masses: jax.Array,
                      pos: jax.Array, cfg: TieredKVConfig,
                      row_mask: jax.Array | None = None) -> dict:
    """OS-exposed static placement: fill the near tier with the profile's
    hottest pages per sequence (the paper's t=0 profiling step), copying the
    pages in — then serve with ``policy="STATIC"`` (no runtime migration).

    profile_masses: (B, n_pages) profiled per-page attention mass.
    pos: current decode position (scalar or ragged (B,) vector) — only
    completely-written pages (page_end <= pos) may be pinned, else the near
    copy would contain unwritten positions that ``tiered_attention`` masks
    out of the far pass (the same guard ``plan_and_migrate`` applies).
    row_mask: optional (B,) bool — only pin these sequences, leaving the
    others' placements untouched (the serving engine pins each slot once,
    at its first planning interval after admission)."""
    cache = dict(cache)
    C = cache["page_of_slot"].shape[1]
    B, n_pages = profile_masses.shape
    pos_b = _pos_vec(pos, B)
    complete = (jnp.arange(n_pages)[None, :] + 1) * cfg.page <= pos_b[:, None]
    profile_masses = jnp.where(complete, profile_masses, 0.0)

    def per_seq(masses, near_k, near_v, far_k, far_v):
        slot_of_page, page_of_slot = preload_static(masses, C)
        slots = jnp.arange(C, dtype=jnp.int32)
        valid = page_of_slot >= 0
        rows = jnp.maximum(page_of_slot, 0)
        near_k, near_v = _copy_pages(near_k, near_v, far_k, far_v, rows,
                                     slots, valid, cfg.page)
        return slot_of_page, page_of_slot, near_k, near_v

    new_sop, new_pos_, new_nk, new_nv = jax.vmap(per_seq)(
        profile_masses, cache["near_k"], cache["near_v"], cache["far_k"],
        cache["far_v"])
    if row_mask is None:
        cache["slot_of_page"], cache["page_of_slot"] = new_sop, new_pos_
        cache["near_k"], cache["near_v"] = new_nk, new_nv
    else:
        r = row_mask
        cache["slot_of_page"] = jnp.where(r[:, None], new_sop,
                                          cache["slot_of_page"])
        cache["page_of_slot"] = jnp.where(r[:, None], new_pos_,
                                          cache["page_of_slot"])
        r4 = r[:, None, None, None]
        cache["near_k"] = jnp.where(r4, new_nk, cache["near_k"])
        cache["near_v"] = jnp.where(r4, new_nv, cache["near_v"])
    return cache


# ===========================================================================
# Paged far tier: a refcounted shared page pool (docs/design.md §2d)
#
# The dense per-slot (B, T, Hkv, hd) far buffer above gives every sequence a
# private copy of every KV page.  The paged mode below restructures the far
# tier into one *pool* of pages shared by all slots:
#
#   pool_k/pool_v : (P, page, Hkv, hd)  — the master copies
#   page_table    : (B, n_pages) int32 — pool page id per slot page, -1 if
#                                        unmapped (per-slot indirection)
#   PagePool      : host-side refcounted allocator (free list + prefix-cache
#                                        retention flags)
#
# Sequences admitted with a shared prompt prefix map the *same* pool pages
# (refcount++) instead of re-storing them, and the near tier becomes global:
# one (C*page,) buffer whose pages are scored by the AGGREGATE attention
# mass of every referencing sequence and promoted once for all tenants —
# the paper's one-IST-many-accesses economics made literal.  Page-table
# dead-entry handling follows the shared-engine sentinel idiom: -1 entries
# route through clamped gathers and are masked from every read.
#
# Since ISSUE 5 the pool is the serving stack's SINGLE SOURCE OF TRUTH
# (docs/design.md §2f): prefill and decode write pool pages directly, no
# dense per-slot master exists, and the near buffers are derived copies
# re-gathered from the pool on mapping changes (`refresh_near_from_pool`).
# The functions below therefore accept either the buffer-carrying dict of
# `init_paged_cache` (the single-layer model object the fuzz suite drives)
# or the mapping-only `init_tier_state` dict plus explicit buffers.
# ===========================================================================


class PagePool:
    """Host-side refcounted allocator over a fixed pool of KV pages.

    ``refcount[p]`` counts the *slots* whose page table references page p —
    the invariant the paged fuzz suite pins.  ``cached[p]`` marks pages
    additionally retained by the radix prefix index (``repro.serve.prefix``)
    after their refcount drops to zero; they stay allocated (re-admissions
    hit them) until the index evicts them under pool pressure.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.refcount = np.zeros(n_pages, np.int32)
        self.cached = np.zeros(n_pages, bool)
        self._free = deque(range(n_pages))

    def available(self) -> int:
        return len(self._free)

    def allocate(self, n: int) -> list[int]:
        """Take n free pages (refcount 1: the mapping slot holds the ref)."""
        if len(self._free) < n:
            raise RuntimeError(
                f"page pool exhausted: want {n}, free {len(self._free)}")
        out = [self._free.popleft() for _ in range(n)]
        self.refcount[out] = 1
        return out

    def acquire(self, pages) -> None:
        """Another slot references already-allocated pages (prefix hit)."""
        for p in pages:
            assert self.refcount[p] > 0 or self.cached[p], \
                f"acquire of unallocated page {p}"
            self.refcount[p] += 1

    def release(self, pages) -> list[int]:
        """Drop one slot reference per page; returns pages actually freed
        (refcount hit zero and the prefix index does not retain them)."""
        freed = []
        for p in pages:
            if p < 0:
                continue
            assert self.refcount[p] > 0, f"release of free page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0 and not self.cached[p]:
                self._free.append(p)
                freed.append(p)
        return freed

    def retain(self, pages) -> None:
        """Prefix-index retention: keep pages allocated at refcount zero."""
        for p in pages:
            self.cached[p] = True

    def drop_cached(self, pages) -> list[int]:
        """Prefix-index eviction; returns pages freed to the pool."""
        freed = []
        for p in pages:
            self.cached[p] = False
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed


def init_tier_state(n_slots: int, n_pages: int, pool_pages: int,
                    near_pages: int) -> dict:
    """Mapping-only paged tier state: page tables, the global near mapping
    and the policy scores — WITHOUT pool or near buffers.  The serving
    engine owns those separately (per layer) since the ownership inversion
    (ISSUE 5); every ``paged_*`` function accepts this dict plus explicit
    buffers, or the full buffer-carrying dict of ``init_paged_cache`` (the
    single-layer model object the fuzz suite drives)."""
    return {
        "page_table": -jnp.ones((n_slots, n_pages), jnp.int32),
        "slot_of_page": -jnp.ones((pool_pages,), jnp.int32),
        "page_of_slot": -jnp.ones((near_pages,), jnp.int32),
        "scores": jnp.zeros((pool_pages,), jnp.float32),
        "last_use": jnp.zeros((pool_pages,), jnp.float32),
        "step": jnp.zeros((), jnp.int32),
        "migrations": jnp.zeros((), jnp.int32),
    }


def init_paged_cache(cfg: TieredKVConfig, n_slots: int, n_pages: int,
                     pool_pages: int, n_kv_heads: int, head_dim: int,
                     dtype=jnp.bfloat16) -> dict:
    """Device state for the paged far tier + global near tier."""
    C = cfg.near_pages
    return {
        **init_tier_state(n_slots, n_pages, pool_pages, C),
        "pool_k": jnp.zeros((pool_pages, cfg.page, n_kv_heads, head_dim),
                            dtype),
        "pool_v": jnp.zeros((pool_pages, cfg.page, n_kv_heads, head_dim),
                            dtype),
        "near_k": jnp.zeros((C * cfg.page, n_kv_heads, head_dim), dtype),
        "near_v": jnp.zeros((C * cfg.page, n_kv_heads, head_dim), dtype),
    }


def paged_append_token(cache: dict, k_new: jax.Array, v_new: jax.Array,
                       pos: jax.Array, cfg: TieredKVConfig) -> dict:
    """Append one token's K/V through the page table into the pool.

    k_new/v_new: (B, 1, Hkv, hd); pos: (B,) per-slot positions.  Writes to
    unmapped pages — and to positions at/past the cache capacity, whose
    page index would otherwise clamp onto the LAST page and corrupt it —
    are dropped (out-of-bounds sentinel)."""
    cache = dict(cache)
    pos = _pos_vec(pos, k_new.shape[0])
    P = cache["pool_k"].shape[0]
    n_pages = cache["page_table"].shape[1]
    j = pos // cfg.page
    pid = jnp.take_along_axis(cache["page_table"], j[:, None], axis=1)[:, 0]
    safe = jnp.where((pid >= 0) & (j < n_pages), pid, P)
    off = pos % cfg.page
    cache["pool_k"] = cache["pool_k"].at[safe, off].set(k_new[:, 0],
                                                        mode="drop")
    cache["pool_v"] = cache["pool_v"].at[safe, off].set(v_new[:, 0],
                                                        mode="drop")
    return cache


def paged_far_view(cache: dict, cfg: TieredKVConfig):
    """Materialize each slot's far cache from the pool via its page table.

    Returns (far_k, far_v) of shape (B, n_pages*page, Hkv, hd); unmapped
    pages come out as page 0's content and MUST be masked by the caller
    (every caller masks on ``page_table >= 0``)."""
    pt = cache["page_table"]
    B, n_pages = pt.shape
    if cfg.gather_kernel:
        # the kernel gets the RAW table: its -1 => zeros contract is live
        # (the XLA path below clamps instead — either way, unmapped content
        # is arbitrary and masked)
        from repro.kernels.paged_gather import paged_gather
        interpret = jax.default_backend() == "cpu"
        far_k = paged_gather(cache["pool_k"], pt, interpret=interpret,
                             mesh=cfg.mesh)
        far_v = paged_gather(cache["pool_v"], pt, interpret=interpret,
                             mesh=cfg.mesh)
        return far_k, far_v
    safe = jnp.maximum(pt, 0)
    _, page, Hkv, hd = cache["pool_k"].shape
    far_k = cache["pool_k"][safe].reshape(B, n_pages * page, Hkv, hd)
    far_v = cache["pool_v"][safe].reshape(B, n_pages * page, Hkv, hd)
    return far_k, far_v


def _front_pack_walk(visit: jax.Array, arrays: dict) -> dict:
    """Front-pack per-slot page walks in page order: for each (B, n_pages)
    array in ``arrays``, keep entries where ``visit`` holds, packed to the
    front (stable — non-visited entries key past the end and come out as
    the array's masked-fill value).  Shared by the READ walk
    (``paged_step_metadata``: mapped & ~promoted & live) and the SCORE walk
    (``paged_score_walk``: mapped & live) so the packing contract the
    kernels rely on cannot desynchronize between them.  Adds ``"len"``:
    (B,) i32 visited count."""
    B, n_pages = visit.shape
    j = jnp.arange(n_pages)
    order = jnp.argsort(jnp.where(visit, j[None, :], n_pages), axis=1)
    out = {k: jnp.take_along_axis(v, order, axis=1).astype(jnp.int32)
           for k, v in arrays.items()}
    out["len"] = visit.sum(axis=1).astype(jnp.int32)
    return out


def paged_step_metadata(cache: dict, lengths: jax.Array,
                        cfg: TieredKVConfig,
                        append_pos: jax.Array | None = None,
                        pool_pages: int | None = None) -> dict:
    """Per-decode-step read-path metadata — small int arrays computed ONCE
    per step from ``(page_table, slot_of_page, page_of_slot, lengths)`` and
    shared by every layer's read (fused kernel inputs AND the dense oracle's
    masks).  Nothing ``(B, n_pages, C)``-shaped is built here or downstream
    (pinned by tests/test_fused_serving.py).

    lengths: (B,) live token count per slot (callers reading "tokens < pos"
    pass ``pos``; the serving decode step passes ``pos + 1`` so the token
    appended this step is attended, matching ``decode_attention``).

    Returns:
      walk_pid  (B, n_pages) i32 : pool ids of the slot's mapped,
                                   NON-promoted, live pages, front-packed in
                                   page order; entries past walk_len unused
      walk_live (B, n_pages) i32 : live rows of each walked page (the
                                   partial-last-page mask, 1..page)
      walk_len  (B,) i32         : number of far pages to walk
      j_of      (B, C) i32       : slot-page index resident in near slot c
                                   for this sequence (-1: not a tenant)
      near_live (B, C) i32       : live rows this sequence reads from near
                                   slot c (0 masks the panel)
      mapped / promoted (B, n_pages) bool : the underlying page states
      pt        (B, n_pages) i32  : the raw page table (the dense
                                   pool-native read path materializes its
                                   per-layer far view from it)
      append_pid/append_off (B,) i32 (only with ``append_pos``): the pool
        page + in-page offset the step's new token writes through the page
        table (sentinel P for unmapped/out-of-range — ``mode="drop"``).

    ``cache`` may be a mapping-only tier-state dict (no pool buffers) —
    pass ``pool_pages`` explicitly then (the serving engine owns the pool
    buffers separately since the ownership inversion, ISSUE 5).
    """
    pt = cache["page_table"]
    B, n_pages = pt.shape
    page = cfg.page
    P = cache["pool_k"].shape[0] if "pool_k" in cache else pool_pages
    assert P is not None, "need pool_k in cache or an explicit pool_pages"
    C = cache["page_of_slot"].shape[0]
    lengths = _pos_vec(lengths, B)

    mapped = pt >= 0
    sop_of_page = cache["slot_of_page"][jnp.maximum(pt, 0)]       # (B,n_pages)
    promoted = mapped & (sop_of_page >= 0)
    j = jnp.arange(n_pages)
    page_live = jnp.clip(lengths[:, None] - j[None, :] * page, 0, page)
    visit = mapped & ~promoted & (page_live > 0)
    walk = _front_pack_walk(visit, {"pid": jnp.where(visit, pt, 0),
                                    "live": jnp.where(visit, page_live, 0)})
    walk_pid, walk_live, walk_len = walk["pid"], walk["live"], walk["len"]

    # near tenancy by SCATTER (j_of[b, near_slot_of(b,j)] = j), not by the
    # (B, n_pages, C) equality tensor the per-layer path used to rebuild
    near_slot = jnp.where(promoted, sop_of_page, C)               # (B,n_pages)
    j_of = jnp.full((B, C), -1, jnp.int32).at[
        jnp.arange(B)[:, None], near_slot].set(
            jnp.broadcast_to(j[None, :], (B, n_pages)).astype(jnp.int32),
            mode="drop")
    near_live = jnp.where(
        j_of >= 0, jnp.clip(lengths[:, None] - j_of * page, 0, page), 0)

    meta = {"walk_pid": walk_pid.astype(jnp.int32),
            "walk_live": walk_live.astype(jnp.int32),
            "walk_len": walk_len,
            "j_of": j_of, "near_live": near_live.astype(jnp.int32),
            "mapped": mapped, "promoted": promoted,
            "pt": pt.astype(jnp.int32)}
    if append_pos is not None:
        append_pos = _pos_vec(append_pos, B)
        ja = append_pos // page
        pid = jnp.take_along_axis(pt, jnp.minimum(ja, n_pages - 1)[:, None],
                                  axis=1)[:, 0]
        meta["append_pid"] = jnp.where((pid >= 0) & (ja < n_pages), pid, P)
        meta["append_off"] = append_pos % page
    return meta


def _paged_masks(cache: dict, pos: jax.Array, cfg: TieredKVConfig,
                 meta: dict | None = None):
    """(far_live, near_live) boolean masks for the DENSE paged read path,
    derived from the hoisted per-step metadata.

    far_live (B, T): token is mapped, before the slot's position, and its
    page is NOT near-resident.  near_live (B, C*page): the near slot holds a
    page of this sequence and the token is before the slot's position (the
    global near tier serves every tenant of a promoted page)."""
    B = cache["page_table"].shape[0]
    page = cfg.page
    pos = _pos_vec(pos, B)
    if meta is None:
        meta = paged_step_metadata(cache, pos, cfg)
    T = cache["page_table"].shape[1] * page
    tok = jnp.arange(T)
    far_live = ((tok[None, :] < pos[:, None])
                & jnp.repeat(meta["mapped"] & ~meta["promoted"], page,
                             axis=1))
    near_live = (jnp.arange(page)[None, None, :]
                 < meta["near_live"][:, :, None])
    return far_live, near_live.reshape(B, -1)


def paged_tiered_attention(cache: dict, q: jax.Array, pos: jax.Array,
                           cfg: TieredKVConfig,
                           meta: dict | None = None) -> jax.Array:
    """Two-tier decode attention over the paged far pool + global near tier.

    Exactly standard attention over each slot's live prefix: pages resident
    in the (shared) near buffer are served there for *every* referencing
    sequence and masked out of the far pass; the LSE merge is exact.

    ``cfg.fused_kernel``: read through the page-table-walking Pallas kernel
    (`kernels.paged_attention`) — no far-view materialization; only the
    slot's live, non-promoted pages transit VMEM.  Default: the dense XLA
    path (the oracle the kernel is validated against).  ``meta``: optionally
    pass a precomputed ``paged_step_metadata`` (the serving engine computes
    it once per step and shares it across layers)."""
    B = q.shape[0]
    if meta is None:
        meta = paged_step_metadata(cache, pos, cfg)
    if cfg.fused_kernel:
        from repro.kernels.paged_attention import paged_attention_stats
        stats = paged_attention_stats(
            q, cache["pool_k"], cache["pool_v"],
            cache["near_k"], cache["near_v"], meta, mesh=cfg.mesh)
        return ref.merge_attention_stats([stats])
    far_k, far_v = paged_far_view(cache, cfg)
    far_live, near_live = _paged_masks(cache, pos, cfg, meta=meta)
    nk = jnp.broadcast_to(cache["near_k"][None],
                          (B,) + cache["near_k"].shape)
    nv = jnp.broadcast_to(cache["near_v"][None],
                          (B,) + cache["near_v"].shape)
    stats_n = _far_stats(q, nk, nv, near_live)
    stats_f = _far_stats(q, far_k, far_v, far_live)
    return ref.merge_attention_stats([stats_n, stats_f])


def paged_score_walk(cache: dict, pos: jax.Array,
                     cfg: TieredKVConfig) -> dict:
    """SCORE walk list: every mapped page with live rows, front-packed in
    page order — near-resident pages INCLUDED (retention scores must stay
    fresh), which is what distinguishes it from the read walk
    (``paged_step_metadata``, which skips promoted pages).

    Returns score_pid/score_live/score_j (B, n_pages) i32 and score_len
    (B,) i32; ``score_j`` is each entry's slot-page index (sentinel
    n_pages past score_len) so callers can scatter per-entry masses back
    to (B, n_pages) positions."""
    pt = cache["page_table"]
    B, n_pages = pt.shape
    page = cfg.page
    pos_b = _pos_vec(pos, B)
    j = jnp.arange(n_pages)
    page_live = jnp.clip(pos_b[:, None] - j[None, :] * page, 0, page)
    visit = (pt >= 0) & (page_live > 0)
    walk = _front_pack_walk(
        visit, {"pid": jnp.where(visit, pt, 0),
                "live": jnp.where(visit, page_live, 0),
                "j": jnp.where(visit, jnp.broadcast_to(j[None, :],
                                                       (B, n_pages)),
                               n_pages)})
    return {"score_pid": walk["pid"], "score_live": walk["live"],
            "score_j": walk["j"], "score_len": walk["len"]}


def paged_page_masses(q: jax.Array, cache: dict, pos: jax.Array,
                      cfg: TieredKVConfig) -> jax.Array:
    """Per-slot per-page attention mass over the paged far pool.

    Returns (B, n_pages) f32 — near-resident pages included (scores stay
    fresh), unmapped pages zero.  The *aggregate* pool-page mass that drives
    planning is derived by ``aggregate_pool_masses``.

    ``cfg.fused_kernel``: score through the pool-native page-mass reduction
    kernel (`kernels.paged_masses`) — walks the page table like the fused
    read, touching only live mapped K pages, with NO far-view
    materialization.  Default: the XLA materializing path (the oracle)."""
    B, H, _ = q.shape
    pt = cache["page_table"]
    n_pages = pt.shape[1]
    page = cfg.page
    if cfg.fused_kernel:
        from repro.kernels.paged_masses import paged_masses
        walk = paged_score_walk(cache, pos, cfg)
        interpret = jax.default_backend() == "cpu"
        mass = paged_masses(q, cache["pool_k"], walk["score_pid"],
                            walk["score_live"], walk["score_len"],
                            interpret=interpret, mesh=cfg.mesh)   # (B, W)
        out = jnp.zeros((B, n_pages), jnp.float32).at[
            jnp.arange(B)[:, None], walk["score_j"]].add(mass, mode="drop")
        return out / max(H, 1)
    far_k, _ = paged_far_view(cache, cfg)
    T = far_k.shape[1]
    live = ((jnp.arange(T)[None, :] < _pos_vec(pos, B)[:, None])
            & jnp.repeat(pt >= 0, page, axis=1))
    mass = _token_masses(q, far_k, live)
    return mass.reshape(B, n_pages, page).sum(-1) / max(H, 1)


def aggregate_pool_masses(cache: dict, masses: jax.Array, pos: jax.Array,
                          cfg: TieredKVConfig) -> jax.Array:
    """Scatter per-slot page masses onto pool pages: a shared page is scored
    by the SUM of every referencing sequence's attention mass on it.  Only
    completely-written pages contribute (the same promotion guard the
    monolithic path applies — a partial page must not enter the near tier).
    """
    pt = cache["page_table"]
    B, n_pages = pt.shape
    P = cache["pool_k"].shape[0]
    pos_b = _pos_vec(pos, B)
    complete = (jnp.arange(n_pages)[None, :] + 1) * cfg.page \
        <= pos_b[:, None]
    m = jnp.where(complete & (pt >= 0), masses, 0.0)
    pid = jnp.where(pt >= 0, pt, P)
    return jnp.zeros((P,), jnp.float32).at[pid.ravel()].add(
        m.ravel(), mode="drop")


def paged_plan_and_migrate(cache: dict, q: jax.Array, pos: jax.Array,
                           cfg: TieredKVConfig, idle=True,
                           masses: jax.Array | None = None) -> dict:
    """One planning interval over the POOL page population (jittable).

    The shared vectorized engine (`repro.tier.jax_engine`) runs once over
    all P pool pages with the global (C,) near mapping — a hot page shared
    by many sequences aggregates their attention mass and is promoted once
    for all of them.  ``masses``: optionally pass a precomputed
    ``paged_page_masses`` result."""
    if cfg.policy.upper() == "STATIC":
        return cache          # per-slot pinning is the engine's host path
    cache = dict(cache)
    if masses is None:
        masses = paged_page_masses(q, cache, pos, cfg)
    acts = aggregate_pool_masses(cache, masses, pos, cfg) * cfg.interval
    cache["scores"] = ema_update(cache["scores"], acts, cfg.costs)
    cache["last_use"] = jnp.where(acts > 0, cache["step"].astype(jnp.float32),
                                  cache["last_use"])
    cache["step"] = cache["step"] + 1
    sc_like = cfg.policy.upper() in ("SC", "WMC")
    pages, slots, valid = plan_promotions(
        cache["scores"], cache["slot_of_page"], cache["page_of_slot"],
        cfg.costs, cfg.max_promotions, policy=cfg.policy,
        last_use=cache["last_use"],
        accessed=(acts > 0) if sc_like else None, idle=idle)
    cache["slot_of_page"], cache["page_of_slot"] = apply_promotions(
        cache["slot_of_page"], cache["page_of_slot"], pages, slots, valid)
    cache["near_k"], cache["near_v"] = _copy_pool_pages(
        cache["near_k"], cache["near_v"], cache["pool_k"], cache["pool_v"],
        pages, slots, valid, cfg.page)
    cache["migrations"] = cache["migrations"] + valid.sum().astype(jnp.int32)
    return cache


def paged_pin_pages(cache: dict, pages, slots, cfg: TieredKVConfig) -> dict:
    """STATIC placement on the pool: map the given pool pages into the given
    (free) near slots and copy their contents in.  ``pages``/``slots`` are
    host lists — the engine's per-slot first-interval pinning pass.

    A mapping-only tier-state dict (no pool/near buffers) updates just the
    mapping; the caller re-derives its near buffers from the pool
    (``refresh_near_from_pool``) — the pool-native engine path."""
    if not len(pages):
        return cache
    cache = dict(cache)
    pages_a = jnp.asarray(list(pages), jnp.int32)
    slots_a = jnp.asarray(list(slots), jnp.int32)
    valid = jnp.ones((len(pages),), bool)
    cache["slot_of_page"] = cache["slot_of_page"].at[pages_a].set(slots_a)
    cache["page_of_slot"] = cache["page_of_slot"].at[slots_a].set(pages_a)
    if "pool_k" in cache:
        cache["near_k"], cache["near_v"] = _copy_pool_pages(
            cache["near_k"], cache["near_v"], cache["pool_k"],
            cache["pool_v"], pages_a, slots_a, valid, cfg.page)
    return cache


def paged_release_pages(cache: dict, pages, cfg: TieredKVConfig) -> dict:
    """Reset tier state for pool pages leaving allocation (freed at retire
    or evicted from the prefix index): zero their scores, and demote any
    near-resident ones — compacting the near mapping so occupied near slots
    remain a prefix (the invariant every read depends on).

    Host-side (numpy mapping surgery + one device reorder of the near
    buffers); runs at admission/retirement boundaries, never per step.  A
    mapping-only tier-state dict (no near buffers) gets the surgery alone;
    the caller re-derives its near buffers from the pool
    (``refresh_near_from_pool``) — the pool-native engine path."""
    pages = [int(p) for p in pages]
    if not pages:
        return cache
    cache = dict(cache)
    P = cache["scores"].shape[0]
    C = cache["page_of_slot"].shape[0]
    page = cfg.page
    scores = np.array(cache["scores"])
    last_use = np.array(cache["last_use"])
    sop = np.array(cache["slot_of_page"])
    ros = np.array(cache["page_of_slot"])
    scores[pages] = 0.0
    last_use[pages] = 0.0
    drop_slots = {int(sop[p]) for p in pages if sop[p] >= 0}
    if drop_slots:
        keep = [c for c in range(C) if ros[c] >= 0 and c not in drop_slots]
        perm = np.arange(C)
        new_ros = -np.ones(C, np.int32)
        new_sop = -np.ones(P, np.int32)
        for i, c in enumerate(keep):
            perm[i] = c
            new_ros[i] = ros[c]
            new_sop[ros[c]] = i
        if "near_k" in cache:
            shape = cache["near_k"].shape
            nk = cache["near_k"].reshape(C, page, *shape[1:])
            nv = cache["near_v"].reshape(C, page, *shape[1:])
            cache["near_k"] = jnp.take(nk, perm, axis=0).reshape(shape)
            cache["near_v"] = jnp.take(nv, perm, axis=0).reshape(shape)
        sop, ros = new_sop, new_ros
    sop[pages] = -1
    cache["scores"] = jnp.asarray(scores)
    cache["last_use"] = jnp.asarray(last_use)
    cache["slot_of_page"] = jnp.asarray(sop)
    cache["page_of_slot"] = jnp.asarray(ros)
    return cache


def refresh_near_from_pool(pool_k: jax.Array, pool_v: jax.Array,
                           page_of_slot: jax.Array):
    """Re-derive near-tier buffers from the pool under the current global
    near mapping — the pool-native near refresh (the pool IS the master
    copy, so a full re-gather is equivalent to incremental page copies).

    pool_k/pool_v: (..., P, page, Hkv, hd) — a leading layer axis is
    supported (the serving engine keeps per-layer pools).  Returns
    (near_k, near_v) of shape (..., C*page, Hkv, hd); unoccupied near
    slots come out zeroed.  Runs only when the mapping changes
    (plan / pin / release / admit / retire), never per decode step."""
    safe = jnp.maximum(page_of_slot, 0)
    occ = page_of_slot >= 0
    nk = jnp.take(pool_k, safe, axis=-4)
    nv = jnp.take(pool_v, safe, axis=-4)
    occ_b = occ[(...,) + (None,) * 3]
    nk = jnp.where(occ_b, nk, 0)
    nv = jnp.where(occ_b, nv, 0)
    *lead, C, page, Hkv, hd = nk.shape
    shape = (*lead, C * page, Hkv, hd)
    return nk.reshape(shape), nv.reshape(shape)
