"""Jittable interval-mode tier engine for the TPU substrate (docs/tier.md).

The TPU runtime (tiered KV cache, tiered embedding table) cannot afford a
policy decision per access; instead a planning pass runs every N decode steps
(the paper's BBC samples activation counts per interval in hardware — here
the "interval" is N steps).  All four policies run through the shared
decision core in `repro.tier.rules` over fixed-shape arrays, so the whole
pass jits and vmaps:

    ema_update       : decayed activation scores.
    plan_promotions  : (rows, slots, valid) for up to K migrations.
    apply_promotions : commit the mapping updates (drop-sentinel scatters).
    preload_static   : the OS-exposed mechanism's t=0 profile placement.

``policy`` is a static Python string (chooses the compiled program); WMC's
``idle`` gate may be a traced boolean.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tier import rules
from repro.tier.costs import TierCosts

ema_update = rules.ema_update


def plan_promotions(scores: jax.Array, slot_of_row: jax.Array,
                    row_of_slot: jax.Array, costs: TierCosts,
                    max_promotions: int, *, policy: str = "BBC",
                    last_use: jax.Array | None = None,
                    accessed: jax.Array | None = None,
                    idle=True, dirty: jax.Array | None = None):
    """One planning step; see ``rules.plan_promotions_xp`` for semantics.

    scores:      (N,) f32 — EMA activation counts per row.
    slot_of_row: (N,) int32 — near slot per row, -1 if far.
    row_of_slot: (C,) int32 — far row per near slot, -1 if empty.
    """
    return rules.plan_promotions_xp(
        jnp, policy, scores, slot_of_row, row_of_slot, costs,
        max_promotions, last_use=last_use, accessed=accessed, idle=idle,
        dirty=dirty)


def apply_promotions(slot_of_row: jax.Array, row_of_slot: jax.Array,
                     promote_rows: jax.Array, victim_slots: jax.Array,
                     valid: jax.Array):
    """Update the two mapping arrays after a planning step.

    Invalid/sentinel writes are routed to an out-of-bounds index and dropped
    (note: -1 would *wrap* in JAX indexing, so N/C sentinels are used).
    """
    N = slot_of_row.shape[0]
    C = row_of_slot.shape[0]
    old_rows = row_of_slot[victim_slots]
    # evict: clear slot pointers of displaced rows (skip empty slots)
    evict_idx = jnp.where(valid & (old_rows >= 0), old_rows, N)
    slot_of_row = slot_of_row.at[evict_idx].set(-1, mode="drop")
    # place: write new mappings
    place_rows = jnp.where(valid, promote_rows, N)
    slot_of_row = slot_of_row.at[place_rows].set(victim_slots, mode="drop")
    slot_idx = jnp.where(valid, victim_slots, C)
    row_of_slot = row_of_slot.at[slot_idx].set(
        jnp.where(valid, promote_rows, -1), mode="drop")
    return slot_of_row, row_of_slot


def preload_static(counts: jax.Array, capacity: int):
    """OS-exposed static placement: map the ``capacity`` hottest rows (by
    profiled count) to near slots 0..C-1.  counts: (N,) — returns
    (slot_of_row (N,), row_of_slot (C,))."""
    N = counts.shape[0]
    top_counts, rows = jax.lax.top_k(counts, capacity)
    valid = top_counts > 0
    row_of_slot = jnp.where(valid, rows, -1).astype(jnp.int32)
    place = jnp.where(valid, rows, N)
    slot_of_row = (-jnp.ones((N,), jnp.int32)).at[place].set(
        jnp.arange(capacity, dtype=jnp.int32), mode="drop")
    return slot_of_row, row_of_slot
