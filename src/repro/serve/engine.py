"""Continuous-batching tiered-KV serving engine (the TL-DRAM runtime).

The paper's near segment only pays off when many concurrent accesses share
the fast path; the serving analogue is a *slot pool*: a fixed batch of
decode slots that independent sequences are admitted into and retired from,
so one batched decode step serves every in-flight sequence at once (ragged
``pos`` — each slot sits at its own position).

Since PR 3 the far tier behind the slots is a **refcounted shared page
pool** (``core.tiered_kv`` paged mode, docs/design.md §2d): each slot's far
view is a page table into the pool, and a radix prefix cache
(``serve.prefix``) lets admissions reuse already-written pages for shared
prompt prefixes — refcount++, prefill **only the suffix** (the modeled
clock and the real compute both drop), and the suffix-chunked
``transformer.prefill`` reproduces the full-prefill cache rows
bit-identically.  The near tier is global: a hot shared page is scored by
the aggregate attention mass of every referencing sequence and promoted
ONCE for all tenants — the paper's one-IST-many-accesses economics.

Scheduler loop (``ServingEngine.run``):

  admit    : pop arrived requests into free slots — match the prompt
             against the radix prefix cache, map shared pages, prefill the
             suffix (bucketed jit) into the slot's rows, seed the first
             token, cache the prompt's new full pages in the pool.
  decode   : ONE batched ``transformer.decode_step`` with per-slot ``pos``
             (ragged state threaded through RoPE, cache scatter and the
             attention mask) emits a token for every active slot.
  maintain : every ``tier.interval`` steps, refresh the pool master copies
             from the slot rows, score per-page attention mass with the
             step's layer-0 queries, aggregate it onto pool pages, and run
             the configured policy (SC/WMC/BBC via
             ``paged_plan_and_migrate``; STATIC pins each slot once at its
             first interval) — the amortized IST.
  retire   : finished sequences release their page refs; pages at refcount
             zero are freed unless the prefix cache retains them for
             re-arrivals (multi-turn chat keeps hitting, and a page's near
             residency survives its tenants).

The decode path is *exact* (full-cache attention with ragged masks), so
emitted tokens match the single-sequence ``greedy_generate`` reference
bit-for-bit with sharing on or off (pinned in
tests/test_prefix_sharing.py); the paged tiered state drives the byte-cost
model and, optionally, a read-path verification probe
(``verify_tiered_read``).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import tiered_kv as tkv
from repro.core.tiered_kv import PagePool, TieredKVConfig
from repro.kernels import ref
from repro.models import transformer
from repro.serve.metrics import CostModel, ServingReport
from repro.serve.prefix import RadixPrefixCache
from repro.serve.trace import Request


@dataclass
class ServingConfig:
    n_slots: int = 4
    max_len: int = 256
    prefill_bucket: int = 32      # prompt lengths pad up to a multiple of
                                  # this (bounds jit recompiles; exact —
                                  # causal attention ignores the pad tail)
    tier: TieredKVConfig = field(default_factory=TieredKVConfig)
    cost: CostModel = field(default_factory=CostModel)
    share_prefix: bool = False    # radix prefix cache over the page pool:
                                  # admissions reuse shared prompt pages and
                                  # prefill only the suffix
    pool_pages: int | None = None  # far-pool capacity; default covers every
                                   # slot fully plus retention slack for the
                                   # prefix cache
    verify_tiered_read: bool = False   # probe paged tiered read vs
                                       # monolithic attention at every
                                       # planning pass


@dataclass
class _Slot:
    req: Request
    emitted: list
    last_emit: float              # modeled clock of the last emitted token


class ServingEngine:
    def __init__(self, params, arch: ArchConfig, cfg: ServingConfig):
        assert arch.n_heads and arch.ssm is None, \
            "serving engine requires an attention-family architecture"
        assert not arch.sliding_window, \
            "ragged slot pool + ring buffer not supported yet"
        assert cfg.max_len % cfg.tier.page == 0, \
            "max_len must be a page multiple"
        assert not (cfg.share_prefix and arch.mrope), \
            "prefix sharing needs 1-D positions"
        self.params, self.arch, self.cfg = params, arch, cfg
        self.n_pages = cfg.max_len // cfg.tier.page
        # fused mode (ISSUE 4): the decode step reads through the
        # page-table-walking kernel over PER-LAYER pool/near buffers —
        # far bytes touched per step = live non-promoted page rows only
        self.fused = bool(cfg.tier.fused_kernel)
        # Pool sizing: worst case (no sharing) every slot maps private
        # pages; the slack keeps retired prompts cached for re-arrivals.
        self.pool_pages = cfg.pool_pages if cfg.pool_pages is not None \
            else (cfg.n_slots + 4) * self.n_pages
        assert self.pool_pages >= cfg.n_slots * self.n_pages, \
            "pool must at least cover the slot pool"
        self._decode = jax.jit(
            lambda p, c, b: transformer.decode_step(p, c, b, arch,
                                                    want_aux=True))
        self._plan = jax.jit(
            lambda c, q, pos, idle, m: tkv.paged_plan_and_migrate(
                c, q, pos, cfg.tier, idle=idle, masses=m))
        self._masses = jax.jit(
            lambda q, c, pos: tkv.paged_page_masses(q, c, pos, cfg.tier))
        self._refresh = jax.jit(
            lambda c, k0, v0: tkv.refresh_pool_from_slots(c, k0, v0,
                                                          cfg.tier))
        self._read = jax.jit(
            lambda c, q, pos: tkv.paged_tiered_attention(c, q, pos,
                                                         cfg.tier))
        # jax.jit caches per input shape, so one wrapper covers every
        # prompt-length bucket (and every matched-prefix length)
        from repro.launch.serve import make_suffix_prefill_step
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, arch,
                                             max_len=cfg.max_len))
        self._prefill_sfx = jax.jit(make_suffix_prefill_step(arch,
                                                             cfg.max_len))
        page = cfg.tier.page

        def gather_prefix(pool_k, pool_v, ids):
            """(L,P,page,Hkv,hd) pools + (m,) ids -> (L,1,m*page,Hkv,hd)."""
            k = pool_k[:, ids]
            L, m, _, Hkv, hd = k.shape
            return (k.reshape(L, 1, m * page, Hkv, hd),
                    pool_v[:, ids].reshape(L, 1, m * page, Hkv, hd))

        def write_pages(pool_k, pool_v, k_rows, v_rows, ids):
            """Scatter slot rows (L,T,Hkv,hd) into full-layer pool pages;
            ids: (n_pages,) pool id per prompt page, -1 entries dropped."""
            L, T, Hkv, hd = k_rows.shape
            n = ids.shape[0]
            P = pool_k.shape[1]
            safe = jnp.where(ids >= 0, ids, P)
            rk = k_rows.reshape(L, n, page, Hkv, hd)
            rv = v_rows.reshape(L, n, page, Hkv, hd)
            return (pool_k.at[:, safe].set(rk, mode="drop"),
                    pool_v.at[:, safe].set(rv, mode="drop"))

        self._gather_prefix = jax.jit(gather_prefix)
        self._write_pages = jax.jit(write_pages)

        if self.fused:
            from repro.launch.serve import make_paged_tiered_decode_step
            self._decode_fused = jax.jit(
                make_paged_tiered_decode_step(arch, cfg.tier))
            # per-step read metadata, computed ONCE per tick and shared by
            # every layer: lengths = pos + 1 (the appended token is live),
            # append routing from pos
            self._meta = jax.jit(
                lambda paged, pos: tkv.paged_step_metadata(
                    paged, pos + 1, cfg.tier, append_pos=pos))

            def sync_near(pool_k, pool_v, page_of_slot):
                """Re-derive the per-layer near buffers from the per-layer
                pools under the (just-changed) global near mapping.  The
                near-copy == pool-master invariant makes a full re-gather
                equivalent to incremental page copies; C is small and this
                runs only when the mapping changes (plan/pin/release)."""
                safe = jnp.maximum(page_of_slot, 0)
                occ = (page_of_slot >= 0)[None, :, None, None, None]
                nk = jnp.where(occ, pool_k[:, safe], 0)
                nv = jnp.where(occ, pool_v[:, safe], 0)
                L, C, pg, Hkv, hd = nk.shape
                return (nk.reshape(L, C * pg, Hkv, hd),
                        nv.reshape(L, C * pg, Hkv, hd))

            self._sync_near = jax.jit(sync_near)

    # -- admission ----------------------------------------------------------

    def _admit(self, req: Request, slot: int, clock: float) -> float:
        cfg = self.cfg
        page = cfg.tier.page
        prompt = np.asarray(req.prompt, np.int32)
        S = int(prompt.shape[0])
        assert S + req.max_new_tokens <= cfg.max_len, \
            f"request {req.rid} does not fit max_len={cfg.max_len}"

        # 1. prefix match: reuse already-written pool pages (refcount++)
        matched_ids = [] if self.prefix is None \
            else self.prefix.match(prompt)
        m = len(matched_ids)
        matched = m * page
        if m:
            self.pool.acquire(matched_ids)
        # 2. map the rest of the slot's range onto fresh pages (evicting
        #    LRU cached-idle pages under pressure; their tier state resets)
        if self.prefix is not None:
            fresh, evicted = self.prefix.allocate(self.n_pages - m)
            if evicted:
                self.paged = tkv.paged_release_pages(self.paged, evicted,
                                                     cfg.tier)
        else:
            fresh = self.pool.allocate(self.n_pages - m)
        row = matched_ids + fresh
        self.pt_host[slot] = row
        self.paged["page_table"] = self.paged["page_table"].at[slot].set(
            jnp.asarray(row, jnp.int32))

        # 3. prefill ONLY the suffix (bucket-padded); shared-prefix K/V rows
        #    come from the full-layer pool — real compute drops with matched
        s_len = S - matched
        s_pad = -(-s_len // cfg.prefill_bucket) * cfg.prefill_bucket
        padded = np.zeros((1, s_pad), np.int32)
        padded[0, :s_len] = prompt[matched:]
        if m:
            kpre, vpre = self._gather_prefix(
                self.pool_layers_k, self.pool_layers_v,
                jnp.asarray(matched_ids, jnp.int32))
            positions = matched + np.arange(s_pad, dtype=np.int32)[None]
            logits, pcache = self._prefill_sfx(
                self.params, {"tokens": padded, "positions": positions},
                kpre, vpre)
        else:
            logits, pcache = self._prefill(self.params, {"tokens": padded})
        first = int(jnp.argmax(logits[0, s_len - 1]))
        # write the sequence's K/V rows into the slot pool (positions >= S
        # are zero-padded by prefill and masked by the ragged live mask)
        self.cache["k"] = self.cache["k"].at[:, slot].set(pcache["k"][:, 0])
        self.cache["v"] = self.cache["v"].at[:, slot].set(pcache["v"][:, 0])

        # 4. write the slot's fresh pages into the full-layer pool: the
        #    FUSED read path walks the pool, so it needs every page of the
        #    row (matched shared pages are already there); prefix sharing
        #    additionally indexes the prompt's new full pages for sharers
        if self.fused:
            ids = np.asarray(row, np.int32).copy()
            ids[:m] = -1
            self.pool_layers_k, self.pool_layers_v = self._write_pages(
                self.pool_layers_k, self.pool_layers_v,
                pcache["k"][:, 0], pcache["v"][:, 0], jnp.asarray(ids))
        if self.prefix is not None:
            n_full = S // page
            if n_full > m:
                if not self.fused:   # fused already wrote the whole row
                    ids = -np.ones(self.n_pages, np.int32)
                    ids[m:n_full] = row[m:n_full]
                    self.pool_layers_k, self.pool_layers_v = \
                        self._write_pages(
                            self.pool_layers_k, self.pool_layers_v,
                            pcache["k"][:, 0], pcache["v"][:, 0],
                            jnp.asarray(ids))
                self.prefix.insert(prompt[:n_full * page], row[:n_full])
        self._after_mapping_change()

        self.pos[slot] = S
        self.tok[slot] = first
        self._static_pinned[slot] = False
        clock += cfg.cost.prefill_cost(s_len)
        self.slots[slot] = _Slot(req=req, emitted=[first], last_emit=clock)
        ttft = clock - self._visible_clock[req.rid]
        self.report.token_latencies.append(ttft)
        self.report.ttfts.append(ttft)
        self.report.tokens += 1
        self.report.prefill_tokens += s_len
        self.report.prefill_tokens_full += S
        self.report.prefix_hit_tokens += matched
        self.slot_history.setdefault(slot, []).append(req.rid)
        return clock

    def _retire(self, slot: int):
        st = self.slots[slot]
        self.report.outputs[st.req.rid] = list(st.emitted)
        self.slots[slot] = None
        self.pos[slot] = 0
        self.tok[slot] = 0
        self._near_tokens[slot] = 0
        # drop this slot's page references NOW, not at the next admit: freed
        # pages' decayed scores would otherwise stay promotion-eligible and
        # keep the planning pass migrating (and billing) stale pages.
        # Prefix-cached pages survive at refcount zero (re-arrival hits) —
        # including their near-tier residency.
        pids = [int(p) for p in self.pt_host[slot] if p >= 0]
        freed = self.pool.release(pids)
        if freed:
            self.paged = tkv.paged_release_pages(self.paged, freed,
                                                 self.cfg.tier)
        self.pt_host[slot] = -1
        self.paged["page_table"] = self.paged["page_table"].at[slot].set(-1)
        self._after_mapping_change()
        self.free.append(slot)
        self.free.sort()

    # -- fused-mode bookkeeping ---------------------------------------------

    def _after_mapping_change(self):
        """Fused mode: mark the per-layer near buffers / host residency
        mirror stale after any event that moves the global near mapping or
        the page tables (plan / pin / release / admit / retire).  The
        actual re-sync happens once per tick (``_flush_mapping``) — N
        retires + M admits in one tick cost one gather, not N+M."""
        self._mapping_dirty = True

    def _flush_mapping(self):
        if not (self.fused and self._mapping_dirty):
            return
        self.near_layers_k, self.near_layers_v = self._sync_near(
            self.pool_layers_k, self.pool_layers_v,
            self.paged["page_of_slot"])
        sop = np.asarray(self.paged["slot_of_page"])
        self._promoted_host = (self.pt_host >= 0) \
            & (sop[np.maximum(self.pt_host, 0)] >= 0)
        self._mapping_dirty = False

    def _far_rows_shadow(self) -> int:
        """Host-side recomputation of the fused step's far rows touched:
        per slot, the live rows of its mapped, non-promoted pages (lengths
        = pos + 1: the token appended this step is attended)."""
        lengths = self.pos + 1
        page_start = np.arange(self.n_pages) * self.cfg.tier.page
        live = np.clip(lengths[:, None] - page_start[None, :], 0,
                       self.cfg.tier.page)
        walk = (self.pt_host >= 0) & ~self._promoted_host
        return int((live * walk).sum())

    # -- background tier maintenance ----------------------------------------

    def _pin_static(self, masses: np.ndarray, need: np.ndarray,
                    clock: float) -> float:
        """STATIC: at a slot's first planning interval, place its hottest
        complete pages into FREE global near slots (profile placement — no
        later migration, no eviction of earlier pins)."""
        cfg = self.cfg
        tier = cfg.tier
        ros = np.asarray(self.paged["page_of_slot"])
        sop = np.asarray(self.paged["slot_of_page"])
        free_slots = [c for c in range(ros.shape[0]) if ros[c] < 0]
        complete = ((np.arange(self.n_pages)[None, :] + 1) * tier.page
                    <= self.pos[:, None])
        cand_mass: dict[int, float] = {}
        for b in np.flatnonzero(need):
            for j in range(self.n_pages):
                p = int(self.pt_host[b, j])
                if p >= 0 and complete[b, j] and masses[b, j] > 0 \
                        and sop[p] < 0:
                    cand_mass[p] = cand_mass.get(p, 0.0) + float(masses[b, j])
        ranked = sorted(cand_mass, key=lambda p: -cand_mass[p])
        chosen = ranked[:len(free_slots)]
        if chosen:
            self.paged = tkv.paged_pin_pages(self.paged, chosen,
                                             free_slots[:len(chosen)], tier)
            clock += cfg.cost.migration_cost(len(chosen), tier.page)
            self.report.migrations += len(chosen)  # pin copies are ISTs too
        self._static_pinned |= need
        return clock

    def _maintain(self, q0, clock: float, idle: bool) -> float:
        cfg = self.cfg
        tier = cfg.tier
        active = np.array([s is not None for s in self.slots])
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        # bring the pool master copies up to date with the decode appends
        # (one scatter; shared pages receive identical bytes from any tenant)
        self.paged = self._refresh(self.paged, self.cache["k"][0],
                                   self.cache["v"][0])
        # one scoring pass per interval: the same per-slot masses drive
        # planning/pinning AND the hit-mass metric below
        masses_dev = self._masses(q0, self.paged, pos_vec)
        if tier.policy.upper() == "STATIC":
            need = active & ~self._static_pinned
            if need.any():
                clock = self._pin_static(np.asarray(masses_dev), need, clock)
                self._after_mapping_change()
        else:
            before = int(self.paged["migrations"])
            self.paged = self._plan(self.paged, q0, pos_vec, idle,
                                    masses_dev)
            moved = int(self.paged["migrations"]) - before
            clock += cfg.cost.migration_cost(moved, tier.page)
            self.report.migrations += moved
            if moved:     # mapping unchanged when nothing migrated
                self._after_mapping_change()
        sop = np.asarray(self.paged["slot_of_page"])
        promoted = (self.pt_host >= 0) & (sop[np.maximum(self.pt_host, 0)]
                                          >= 0)              # (B, n_pages)
        self._near_tokens = promoted.sum(axis=1) * tier.page
        # near-tier hit mass over active slots (the paper's near-segment hit
        # rate, in attention-mass units) — a shared promoted page counts for
        # every referencing slot: one IST, many accesses
        if active.any():
            masses = np.asarray(masses_dev)
            tot = masses[active].sum()
            if tot > 0:
                self.report.near_hit_mass.append(
                    float((masses * promoted)[active].sum() / tot))
            if cfg.verify_tiered_read:
                got = self._read(self.paged, q0, pos_vec)
                want = ref.decode_attention_ref(
                    q0[:, None], self.cache["k"][0], self.cache["v"][0],
                    pos_vec)[:, 0]
                err = float(jnp.max(jnp.abs(
                    (got - want)[jnp.asarray(active)])))
                self.report.max_read_err = max(self.report.max_read_err, err)
        return clock

    # -- driver --------------------------------------------------------------

    def run(self, trace: list[Request], scenario: str = "trace") -> ServingReport:
        """Replay an offline arrival trace to completion."""
        cfg = self.cfg
        arch = self.arch
        self.report = ServingReport(scenario=scenario,
                                    policy=cfg.tier.policy,
                                    n_requests=len(trace))
        self.cache = transformer.init_cache(arch, cfg.n_slots, cfg.max_len)
        self.paged = tkv.init_paged_cache(
            cfg.tier, cfg.n_slots, self.n_pages, self.pool_pages,
            arch.n_kv_heads, arch.resolved_head_dim,
            dtype=self.cache["k"].dtype)
        self.pool = PagePool(self.pool_pages)
        self.prefix = RadixPrefixCache(self.pool, cfg.tier.page) \
            if cfg.share_prefix else None
        if cfg.share_prefix or self.fused:
            # Full-layer K/V store indexed by pool page id.  Prefix sharing
            # reads cached prompt pages out of it; the FUSED read path makes
            # it the actual serving far tier (every layer's kernel walks
            # it).  Sizing it to the whole pool trades memory for a flat
            # index; a production deployment would key a smaller store by
            # cached page (the trie already owns that lifecycle).
            hd = arch.resolved_head_dim
            shape = (arch.n_layers, self.pool_pages, cfg.tier.page,
                     arch.n_kv_heads, hd)
            self.pool_layers_k = jnp.zeros(shape, self.cache["k"].dtype)
            self.pool_layers_v = jnp.zeros(shape, self.cache["v"].dtype)
        if self.fused:
            # per-layer global near buffers (layer 0 mirrors self.paged's)
            hd = arch.resolved_head_dim
            nshape = (arch.n_layers, cfg.tier.near_pages * cfg.tier.page,
                      arch.n_kv_heads, hd)
            self.near_layers_k = jnp.zeros(nshape, self.cache["k"].dtype)
            self.near_layers_v = jnp.zeros(nshape, self.cache["v"].dtype)
            # host mirror of per-(slot, page) near residency, re-synced
            # (with the near buffers) once per tick when the mapping moved
            # — drives the independent shadow accounting of far rows
            # touched (ISSUE 4 acceptance)
            self._promoted_host = np.zeros((cfg.n_slots, self.n_pages), bool)
            self._mapping_dirty = False
        self.pt_host = -np.ones((cfg.n_slots, self.n_pages), np.int64)
        self.pos = np.zeros(cfg.n_slots, np.int64)
        self.tok = np.zeros(cfg.n_slots, np.int64)
        self.slots: list[_Slot | None] = [None] * cfg.n_slots
        self.free = list(range(cfg.n_slots))
        self.slot_history = {}
        self._near_tokens = np.zeros(cfg.n_slots, np.int64)
        self._static_pinned = np.zeros(cfg.n_slots, bool)
        self._visible_clock: dict[int, float] = {}

        queue = deque(sorted(trace, key=lambda r: (r.arrival, r.rid)))
        tick, clock, steps = 0, 0.0, 0
        t0 = time.perf_counter()
        while queue or any(s is not None for s in self.slots):
            for req in queue:                  # sorted by arrival: stop early
                if req.arrival > tick:
                    break
                if req.rid not in self._visible_clock:
                    self._visible_clock[req.rid] = clock
            while queue and queue[0].arrival <= tick and self.free:
                clock = self._admit(queue.popleft(), self.free.pop(0), clock)
            # a request may want exactly the prefill token (max_new_tokens=1)
            for b in range(cfg.n_slots):
                st = self.slots[b]
                if st is not None and len(st.emitted) >= st.req.max_new_tokens:
                    self._retire(b)
            active_idx = [b for b, s in enumerate(self.slots) if s is not None]
            if not active_idx:
                if queue:
                    tick = max(tick + 1, queue[0].arrival)  # idle fast-forward
                continue

            self.cache["pos"] = jnp.asarray(self.pos, jnp.int32)
            tokens = {"tokens": jnp.asarray(self.tok[:, None], jnp.int32)}
            if self.fused:
                self._flush_mapping()
                meta = self._meta(self.paged, self.cache["pos"])
                fcache = {**self.cache,
                          "pool_k": self.pool_layers_k,
                          "pool_v": self.pool_layers_v,
                          "near_k": self.near_layers_k,
                          "near_v": self.near_layers_v}
                logits, new_cache, aux = self._decode_fused(
                    self.params, fcache, tokens, meta)
                self.pool_layers_k = new_cache.pop("pool_k")
                self.pool_layers_v = new_cache.pop("pool_v")
                new_cache.pop("near_k")
                new_cache.pop("near_v")
                # the walk's accounting (device) + an independent host
                # shadow: both must equal the live non-promoted page rows
                self.report.far_rows_touched += int(meta["walk_live"].sum())
                self.report.far_rows_host += self._far_rows_shadow()
            else:
                logits, new_cache, aux = self._decode(
                    self.params, self.cache, tokens)
                # the dense step materializes/attends the full far view
                self.report.far_rows_touched += \
                    self.n_pages * cfg.tier.page * cfg.n_slots
            self.report.far_rows_dense += \
                self.n_pages * cfg.tier.page * cfg.n_slots
            self.cache = new_cache
            toks = np.asarray(jnp.argmax(logits, axis=-1))[:, 0]

            live = self.pos[active_idx] + 1
            clock += cfg.cost.decode_step_cost(
                self._near_tokens[active_idx], live)
            steps += 1
            for b in active_idx:
                st = self.slots[b]
                st.emitted.append(int(toks[b]))
                self.report.token_latencies.append(clock - st.last_emit)
                st.last_emit = clock
                self.report.tokens += 1
                self.pos[b] += 1
                self.tok[b] = int(toks[b])
                if len(st.emitted) >= st.req.max_new_tokens:
                    self._retire(b)
            if steps % cfg.tier.interval == 0:
                idle = not (queue and queue[0].arrival <= tick)
                clock = self._maintain(aux["q0"], clock, idle)
            tick += 1

        self.report.steps = steps
        self.report.wall_s = time.perf_counter() - t0
        self.report.modeled_time = clock
        self.report.slot_history = dict(self.slot_history)
        if self.prefix is not None:
            self.report.prefix_lookups = self.prefix.stats.lookups
            self.report.prefix_hits = self.prefix.stats.hits
        return self.report


def sequential_baseline(params, arch: ArchConfig, trace: list[Request],
                        cfg: ServingConfig,
                        scenario: str = "trace") -> ServingReport:
    """The no-batching reference: each request served to completion by
    single-sequence ``greedy_generate`` (B=1), one after another, under the
    same modeled cost landscape (no near tier: every live KV token is
    gather-addressed at ``far_cost``)."""
    from repro.launch.serve import greedy_generate, make_decode_step
    report = ServingReport(scenario=scenario, policy="sequential",
                           n_requests=len(trace))
    step_fn = jax.jit(make_decode_step(arch))
    prefill_fn = jax.jit(
        lambda p, b: transformer.prefill(p, b, arch, max_len=cfg.max_len))
    clock = 0.0
    t0 = time.perf_counter()
    for req in sorted(trace, key=lambda r: (r.arrival, r.rid)):
        toks, _ = greedy_generate(
            params, arch, {"tokens": np.asarray(req.prompt)[None]},
            steps=req.max_new_tokens, max_len=cfg.max_len, step_fn=step_fn,
            prefill_fn=prefill_fn)
        report.outputs[req.rid] = np.asarray(toks)[0].tolist()
        S = int(req.prompt.shape[0])
        # TTFT = modeled prefill cost — the same timebase the engine uses
        # (its TTFT is queueing + prefill; the baseline models no queue).
        ttft = cfg.cost.prefill_cost(S)
        clock += ttft
        last = clock
        report.tokens += 1
        report.token_latencies.append(ttft)
        report.ttfts.append(ttft)
        report.prefill_tokens += S
        report.prefill_tokens_full += S
        for i in range(1, req.max_new_tokens):
            clock += cfg.cost.decode_step_cost(np.zeros(1),
                                               np.asarray([S + i]))
            report.token_latencies.append(clock - last)
            last = clock
            report.tokens += 1
        report.steps += req.max_new_tokens - 1
    report.wall_s = time.perf_counter() - t0
    report.modeled_time = clock
    return report
