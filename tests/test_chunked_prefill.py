"""Chunked admission prefill (ISSUE 8 tentpole).

The load-bearing property: splitting a prompt's prefill into cursor-resumed
chunks — each gathering its prefix rows from the pool and scattering its
pages back — produces pool pages and final logits BIT-IDENTICAL to the
one-shot prefill, for every chunk size, including chunks that do not divide
S and cursors that land mid-page.  On top of that, the engine's chunked
scheduler must emit exactly the synchronous engine's tokens for every
policy and kernel mode (the overlap changes the clock, never the math),
while p99 inter-token latency and p50 TTFT drop on stall-prone traces.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.tiered_kv import TieredKVConfig
from repro.launch.serve import (make_pool_chunk_prefill_step,
                                make_pool_prefill_step)
from repro.models import transformer
from repro.serve import ServingConfig, ServingEngine
from repro.serve.trace import SCENARIOS, Request

PAGE, MAX_LEN = 16, 64
N_PAGES = MAX_LEN // PAGE


def _arch_params(seed=0):
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(seed), arch)
    return arch, params


def _pools(arch, pool_pages=8):
    shape = (arch.n_layers, pool_pages, PAGE, arch.n_kv_heads,
             arch.resolved_head_dim)
    return jnp.zeros(shape, jnp.bfloat16), jnp.zeros(shape, jnp.bfloat16)


class TestChunkStepBitIdentity:
    @pytest.mark.parametrize("chunk", [16, 24, 40, 56])
    def test_pool_rows_and_logits_match_one_shot(self, chunk):
        """S=56 over 16-token pages: chunk=16 does not divide S (final
        chunk is 8), chunk=24 leaves the cursor MID-page (24, 48) so the
        next chunk's prefix slice and boundary-page rewrite are exercised,
        chunk=40 crosses a page boundary inside one chunk, chunk=56 is the
        degenerate one-shot."""
        arch, params = _arch_params()
        S = 56
        toks = np.asarray(
            jax.random.randint(jax.random.key(1), (S,), 0, arch.vocab),
            np.int32)
        prefill = jax.jit(make_pool_prefill_step(arch, MAX_LEN, PAGE))
        chunk_fn = jax.jit(make_pool_chunk_prefill_step(arch, MAX_LEN, PAGE),
                           static_argnames=("t_pre",))
        row = list(range(N_PAGES))           # pages 0..3 hold the prompt

        # one-shot reference
        pk_a, pv_a = _pools(arch)
        pad = np.zeros((1, S), np.int32)
        pad[0] = toks
        ids_full = jnp.asarray(row, jnp.int32)
        logits_a, pk_a, pv_a = prefill(params, {"tokens": pad}, pk_a, pv_a,
                                       ids_full)

        # chunked: resume from the cursor until S
        pk_b, pv_b = _pools(arch)
        c0 = 0
        while c0 < S:
            n = min(chunk, S - c0)
            batch_toks = np.zeros((1, n), np.int32)
            batch_toks[0] = toks[c0:c0 + n]
            p_lo = c0 // PAGE
            p_hi = -(-(c0 + n) // PAGE)
            ids = -np.ones(N_PAGES, np.int32)
            ids[p_lo:p_hi] = row[p_lo:p_hi]
            ids = jnp.asarray(ids)
            if c0 == 0:
                logits_b, pk_b, pv_b = prefill(params,
                                               {"tokens": batch_toks},
                                               pk_b, pv_b, ids)
            else:
                positions = c0 + np.arange(n, dtype=np.int32)[None]
                pre = jnp.arange(-(-c0 // PAGE), dtype=jnp.int32)
                logits_b, pk_b, pv_b = chunk_fn(
                    params, {"tokens": batch_toks, "positions": positions},
                    pk_b, pv_b, pre, ids, t_pre=c0)
            c0 += n

        np.testing.assert_array_equal(
            np.asarray(pk_a, np.float32), np.asarray(pk_b, np.float32),
            err_msg=f"chunk={chunk}: K pool rows diverge from one-shot")
        np.testing.assert_array_equal(
            np.asarray(pv_a, np.float32), np.asarray(pv_b, np.float32),
            err_msg=f"chunk={chunk}: V pool rows diverge from one-shot")
        # the completing chunk's last valid row seeds the first token:
        # bit-identical logits to the one-shot's row S-1
        last_n = S - (S - 1) // chunk * chunk if chunk < S else S
        np.testing.assert_array_equal(
            np.asarray(logits_a, np.float32)[0, S - 1],
            np.asarray(logits_b, np.float32)[0, last_n - 1],
            err_msg=f"chunk={chunk}: first-token logits diverge")


def _stall_trace(vocab, rng):
    """Staggered arrivals with long prompts: the synchronous engine stalls
    every in-flight request at each admission."""
    lens = [48, 24, 56, 24, 48]
    arrivals = [0, 1, 3, 5, 8]
    return [Request(rid=i, arrival=arrivals[i],
                    prompt=rng.integers(0, vocab, lens[i]).astype(np.int32),
                    max_new_tokens=8)
            for i in range(5)]


class TestChunkedEngineTokenParity:
    def _run(self, arch, params, policy, chunk, fused=False, gather=False,
             share=False, trace=None):
        tier = TieredKVConfig(page=PAGE, near_pages=2, interval=3,
                              policy=policy, fused_kernel=fused,
                              gather_kernel=gather)
        cfg = ServingConfig(n_slots=3, max_len=MAX_LEN, prefill_bucket=16,
                            tier=tier, share_prefix=share,
                            prefill_chunk_tokens=chunk,
                            overlap_migration=chunk is not None)
        if trace is None:
            trace = _stall_trace(arch.vocab, np.random.default_rng(7))
        return ServingEngine(params, arch, cfg).run(trace, "stall")

    @pytest.mark.parametrize("policy", ["SC", "WMC", "BBC", "STATIC"])
    def test_tokens_bit_identical_to_sync_all_policies(self, policy):
        arch, params = _arch_params(seed=1)
        sync = self._run(arch, params, policy, chunk=None)
        for chunk in (16, 32):
            got = self._run(arch, params, policy, chunk=chunk)
            assert got.outputs == sync.outputs, \
                f"policy {policy} chunk {chunk}: tokens diverge from sync"
            assert got.prefill_chunks > 0

    @pytest.mark.parametrize("mode", ["gather", "fused"])
    def test_tokens_bit_identical_to_sync_kernel_modes(self, mode):
        arch, params = _arch_params(seed=2)
        kw = dict(fused=mode == "fused", gather=mode == "gather")
        sync = self._run(arch, params, "BBC", chunk=None, **kw)
        got = self._run(arch, params, "BBC", chunk=16, **kw)
        assert got.outputs == sync.outputs, \
            f"{mode}: chunked tokens diverge from sync"

    def test_tokens_bit_identical_with_prefix_sharing(self):
        """Chunked jobs trie-insert completed pages incrementally; the
        shared pages must still reproduce the sync engine's tokens."""
        arch, params = _arch_params(seed=3)
        trace = SCENARIOS["shared_system_prompt"](
            arch.vocab, n_requests=6, sys_len=32, user_len=8,
            max_new_tokens=6, gap=1)
        sync = self._run(arch, params, "BBC", chunk=None, share=True,
                         trace=trace)
        got = self._run(arch, params, "BBC", chunk=16, share=True,
                        trace=trace)
        assert got.outputs == sync.outputs
        assert got.prefix_hit_tokens > 0

    def test_overlap_improves_tail_latency_and_ttft(self):
        """The point of the tentpole: on a bursty trace the chunked +
        overlapped engine must cut p99 inter-token latency (no more
        admission lumps inside the tick) — the full >= 25% acceptance on
        bursty/long_context_stragglers is pinned by the committed bench."""
        arch, params = _arch_params(seed=4)
        trace = SCENARIOS["bursty"](arch.vocab, n_requests=8, prompt_len=24,
                                    max_new_tokens=8, burst=4, burst_gap=24)
        sync = self._run(arch, params, "BBC", chunk=None, trace=trace)
        got = self._run(arch, params, "BBC", chunk=96, trace=trace)
        assert got.outputs == sync.outputs
        assert got.p99_lat < sync.p99_lat, \
            (got.p99_lat, sync.p99_lat)
        assert got.p50_ttft < sync.p50_ttft, \
            (got.p50_ttft, sync.p50_ttft)


class TestDeferralGate:
    def test_hot_queue_defers_then_forces_maintenance(self):
        """The generalized WMC gate: planning passes skip while arrivals or
        chunk jobs are pending, but at most ``defer_limit`` in a row — a
        sustained-load run still migrates."""
        arch, params = _arch_params(seed=5)
        tier = TieredKVConfig(page=PAGE, near_pages=2, interval=2,
                              policy="BBC")
        cfg = ServingConfig(n_slots=2, max_len=MAX_LEN, prefill_bucket=16,
                            tier=tier, prefill_chunk_tokens=16,
                            overlap_migration=True, defer_limit=2)
        rng = np.random.default_rng(11)
        trace = [Request(rid=i, arrival=i, prompt=rng.integers(
            0, arch.vocab, 40).astype(np.int32), max_new_tokens=10)
            for i in range(6)]
        rep = ServingEngine(params, arch, cfg).run(trace, "hot")
        assert rep.migration_deferrals > 0, \
            "a hot queue must defer some planning passes"
        assert rep.migrations > 0, \
            "bounded deferral must still let maintenance through"
