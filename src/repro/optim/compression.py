"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

Standard distributed-optimization trick for bandwidth-bound DP: quantize each
gradient leaf to int8 with a per-leaf f32 scale *before* the cross-replica
reduction (4x wire-bytes reduction), accumulate into int32 via ``psum``, and
carry the quantization residual forward (error feedback) so the bias vanishes
over steps.

Used inside ``shard_map`` over the data axes: per-device gradients in, exact
mean of the quantized values out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_psum(grads, residual, axis_names: tuple[str, ...]):
    """Quantized mean-all-reduce with error feedback.

    grads/residual: pytrees of f32 leaves (per-device partial gradients).
    Returns (reduced_grads, new_residual).
    """
    n = 1
    for ax in axis_names:
        # jax >= 0.5 has lax.axis_size; 0.4.x spells it psum(1, axis).
        n *= (jax.lax.axis_size(ax) if hasattr(jax.lax, "axis_size")
              else jax.lax.psum(1, ax))

    def leaf(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.max(jnp.abs(g)) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.round(g / scale).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale
        # wire format: int8 payload summed in int32, plus the f32 scales
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        scale_sum = jax.lax.psum(scale, axis_names)
        # each replica used its own scale; the unbiased reconstruction uses
        # the mean scale (scales are near-equal for IID shards).
        mean_scale = scale_sum / n
        return q_sum.astype(jnp.float32) * mean_scale / n, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
