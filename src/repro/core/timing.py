"""DRAM timing-constraint sets for the simulator, derived from the calibrated
TL-DRAM circuit model (``repro.core.tldram``).

All times in nanoseconds.  Column-path constants (tCL, tBL, tCCD) follow
DDR3-1066 (1.875 ns clock, BL8) and are independent of the bitline split —
TL-DRAM only changes the cell-array timings (tRCD/tRAS/tRP).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core import tldram

# Column path (DDR3-1066 7-7-7, BL8).
T_CL_NS = 13.125
T_BL_NS = 7.5
T_CCD_NS = 7.5
T_WR_NS = 15.0          # write recovery before PRE
# Refresh (2Gb-class): one all-bank REF every tREFI, occupying tRFC.
T_REFI_NS = 7800.0
T_RFC_NS = 160.0

# Inter-segment transfer: "additional 4ns over tRC" (paper Sec. 4).
IST_EXTRA_NS = 4.0


@dataclass(frozen=True)
class TimingSet:
    """Row timings for one access class."""

    t_rcd: float
    t_ras: float
    t_rp: float
    t_cl: float = T_CL_NS
    t_bl: float = T_BL_NS
    t_wr: float = T_WR_NS

    @property
    def t_rc(self) -> float:
        return self.t_ras + self.t_rp


def _from_model(t: tldram.SegmentTimings) -> TimingSet:
    return TimingSet(t_rcd=t.t_rcd, t_ras=t.t_ras, t_rp=t.t_rp)


def ddr3_baseline(cells: int = tldram.CELLS_PER_BITLINE) -> TimingSet:
    """Commodity long-bitline DRAM (the paper's baseline)."""
    return _from_model(tldram.calibrated_timings("unsegmented", cells))


def short_bitline(cells: int = tldram.TABLE1_NEAR_CELLS) -> TimingSet:
    """Latency-optimized short-bitline DRAM (RLDRAM-class reference)."""
    return _from_model(tldram.calibrated_timings("unsegmented", cells))


def tldram_timings(near_cells: int, total_cells: int = tldram.CELLS_PER_BITLINE,
                   ) -> tuple[TimingSet, TimingSet]:
    """(near, far) timing sets for a TL-DRAM split at ``near_cells``."""
    far_cells = total_cells - near_cells
    near = _from_model(tldram.calibrated_timings("near", near_cells, far_cells))
    far = _from_model(tldram.calibrated_timings("far", far_cells, near_cells))
    return near, far


def ist_duration_ns(far: TimingSet) -> float:
    """Inter-segment transfer occupancy: tRC(far) + 4 ns, channel-free."""
    return far.t_rc + IST_EXTRA_NS
