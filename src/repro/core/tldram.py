"""Circuit-level TL-DRAM bitline model.

Reproduces the latency analysis of Lee et al., "Tiered-Latency DRAM" (HPCA 2013
/ cs.AR 2018 summary): a DRAM bitline is a distributed RC load on the sense
amplifier; splitting it with an isolation transistor yields a *near* segment
(low capacitance -> fast) and a *far* segment (charged through the isolation
transistor's resistance -> slow).

The model is a lumped-node ODE integrated with forward Euler (a tiny
SPICE-alike).  Nodes:

  v_n : near-segment bitline (the sense amplifier lives here)
  v_f : far-segment bitline (only when the isolation transistor is ON)
  v_c : the accessed cell's storage capacitor

Activation of a cell storing '1' proceeds in two phases, matching Fig. 6 of
the paper:

  phase A (charge sharing): wordline on, sense amp off; the cell and bitline
    equilibrate, developing the perturbation dV on the bitline.
  phase B (sensing & amplification): the sense amp drives the bitline (and,
    through the access transistor, the cell) toward V_DD.

Timing-constraint definitions (Sec. 3 of the paper):

  tRCD : ACTIVATE -> bitline reaches the *threshold* voltage 0.75*V_DD
         (column access may begin).
  tRAS : ACTIVATE -> every storage node is *restored* (>= RESTORED_FRAC*V_DD).
  tRP  : PRECHARGE -> every bitline node back within PRECHARGE_TOL of V_DD/2.
  tRC  = tRAS + tRP (row cycle).

The default ``CircuitParams`` are calibrated (see ``calibrate.py``) so that the
four Table-1 design points reproduce the paper's numbers:

  short bitline,  32 cells : tRC = 23.1 ns
  long  bitline, 512 cells : tRC = 52.5 ns
  near segment,   32 cells : tRC = 23.1 ns   (far disconnected)
  far  segment,  480 cells : tRC = 65.8 ns   (through the isolation FET)
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass

import numpy as np

# Voltage landmarks (fractions of V_DD), per the paper's definitions.
SENSE_THRESHOLD_FRAC = 0.75   # "threshold" state: column access allowed
RESTORED_FRAC = 0.95          # "restored" state: charge fully replenished
PRECHARGE_TOL_FRAC = 0.02     # bitline considered precharged within +/-2% of VDD/2

# Reference design points from Table 1 of the paper.
CELLS_PER_BITLINE = 512
TABLE1_NEAR_CELLS = 32
TABLE1_FAR_CELLS = 480
TABLE1_TRC_NS = {
    "short_32": 23.1,
    "long_512": 52.5,
    "near_32": 23.1,
    "far_480": 65.8,
}


@dataclass(frozen=True)
class CircuitParams:
    """Lumped circuit parameters.

    Baselines derived from the Rambus 55nm power model [107] and scaled device
    characteristics [98]; the four *_ohm / c_bl_per_cell_f values are then
    calibrated (``repro.core.calibrate``) against Table 1 of the paper.
    """

    vdd: float = 1.2                     # volts (DDR3 at 55nm)
    c_cell_f: float = 24e-15             # cell storage capacitance (F)
    c_bl_per_cell_f: float = 0.320e-15   # bitline parasitic per attached cell (F)
    c_iso_junction_f: float = 0.80e-15   # junction cap the iso FET adds to the near segment
    r_sense_ohm: float = 45.4e3          # sense-amp drive resistance
    r_pre_ohm: float = 45.4e3            # precharge (equalizer) driver resistance
    r_cell_ohm: float = 140.0e3          # cell access transistor on-resistance
    r_iso_ohm: float = 21208.702         # isolation FET on-resistance (calibrated)
    # Length-independent components (row decode + wordline rise, charge-sharing
    # window before SA enable, precharge-driver turn-on).  Real tRC is strongly
    # sublinear in bitline length (Table 1: 23.1ns @ 32 cells vs 52.5ns @ 512
    # cells => ~21ns fixed floor); these carry that floor.
    t_decode_ns: float = 4.0             # row decoder + wordline rise (fixed)
    t_share_ns: float = 3.0              # charge-sharing window before SA enable
    t_pre_fixed_ns: float = 4.0          # precharge driver turn-on (fixed)
    dt_ns: float = 0.01                  # Euler step

    def c_bl(self, cells: int) -> float:
        """Parasitic capacitance of a bitline segment with ``cells`` cells."""
        return cells * self.c_bl_per_cell_f


@dataclass(frozen=True)
class SegmentTimings:
    """Timing constraints (ns) for one access class, plus the voltage traces."""

    t_rcd: float
    t_ras: float
    t_rp: float

    @property
    def t_rc(self) -> float:
        return self.t_ras + self.t_rp


@dataclass(frozen=True)
class BitlineWaveform:
    """Voltage-vs-time traces, for reproducing Figs. 6 and 7."""

    t_ns: np.ndarray
    v_near: np.ndarray
    v_far: np.ndarray | None   # None when the far segment is floating
    v_cell: np.ndarray | None  # None for precharge (wordline closed)


def _euler_activation(
    p: CircuitParams,
    c_near: float,
    c_far: float | None,
    cell_on_far: bool,
    t_max_ns: float = 400.0,
    dt_ns: float | None = None,
) -> BitlineWaveform:
    """Integrate the activation dynamics (charge sharing + amplification).

    ``c_far is None`` means the isolation transistor is OFF (near-only access or
    an unsegmented bitline, in which case ``c_near`` is the full bitline cap).
    """
    dt_ns = dt_ns if dt_ns is not None else p.dt_ns
    dt = dt_ns * 1e-9
    n_steps = int(t_max_ns / dt_ns)
    t = np.arange(n_steps) * dt_ns

    v_n = np.empty(n_steps)
    v_c = np.empty(n_steps)
    v_f = np.empty(n_steps) if c_far is not None else None

    vn = 0.5 * p.vdd   # bitline precharged
    vc = p.vdd         # cell stores '1'
    vf = 0.5 * p.vdd

    sa_on_step = int(p.t_share_ns / dt_ns)

    for i in range(n_steps):
        v_n[i] = vn
        v_c[i] = vc
        if v_f is not None:
            v_f[i] = vf

        i_sa = (p.vdd - vn) / p.r_sense_ohm if i >= sa_on_step else 0.0
        if c_far is None:
            # Near-only (or unsegmented): cell hangs off the near node.
            i_cell = (vc - vn) / p.r_cell_ohm
            dvn = (i_sa + i_cell) / c_near
            dvc = ((vn - vc) / p.r_cell_ohm) / p.c_cell_f
            vn += dvn * dt
            vc += dvc * dt
        else:
            i_iso = (vf - vn) / p.r_iso_ohm
            if cell_on_far:
                i_cell_far = (vc - vf) / p.r_cell_ohm
                dvn = (i_sa + i_iso) / c_near
                dvf = (-i_iso + i_cell_far) / c_far
                dvc = ((vf - vc) / p.r_cell_ohm) / p.c_cell_f
            else:
                i_cell_near = (vc - vn) / p.r_cell_ohm
                dvn = (i_sa + i_iso + i_cell_near) / c_near
                dvf = (-i_iso) / c_far
                dvc = ((vn - vc) / p.r_cell_ohm) / p.c_cell_f
            vn += dvn * dt
            vf += dvf * dt
            vc += dvc * dt

    return BitlineWaveform(t_ns=t, v_near=v_n, v_far=v_f, v_cell=v_c)


def _euler_precharge(
    p: CircuitParams,
    c_near: float,
    c_far: float | None,
    t_max_ns: float = 400.0,
    dt_ns: float | None = None,
) -> BitlineWaveform:
    """Integrate the precharge dynamics (drive every bitline node to VDD/2)."""
    dt_ns = dt_ns if dt_ns is not None else p.dt_ns
    dt = dt_ns * 1e-9
    n_steps = int(t_max_ns / dt_ns)
    t = np.arange(n_steps) * dt_ns

    v_n = np.empty(n_steps)
    v_f = np.empty(n_steps) if c_far is not None else None

    vn = p.vdd           # restored high after the access
    vf = p.vdd
    v_tgt = 0.5 * p.vdd

    for i in range(n_steps):
        v_n[i] = vn
        if v_f is not None:
            v_f[i] = vf
        i_pre = (v_tgt - vn) / p.r_pre_ohm
        if c_far is None:
            vn += (i_pre / c_near) * dt
        else:
            i_iso = (vf - vn) / p.r_iso_ohm
            vn += ((i_pre + i_iso) / c_near) * dt
            vf += ((-i_iso) / c_far) * dt

    return BitlineWaveform(t_ns=t, v_near=v_n, v_far=v_f, v_cell=None)


def _first_crossing(t_ns: np.ndarray, v: np.ndarray, level: float) -> float:
    idx = np.argmax(v >= level)
    if v[idx] < level:
        raise ValueError("voltage never reached target level; t_max too small")
    return float(t_ns[idx])


def _first_stays_above(t_ns: np.ndarray, v: np.ndarray, level: float) -> float:
    """First time after which v stays >= level (handles the charge-sharing dip:
    a cell storing '1' starts at VDD, dips while sharing, then restores)."""
    below = v < level
    if not below.any():
        return 0.0
    last_below = len(below) - 1 - np.argmax(below[::-1])
    if last_below == len(below) - 1:
        raise ValueError("voltage never restored; t_max too small")
    return float(t_ns[last_below + 1])


def _first_settled(t_ns: np.ndarray, v: np.ndarray, target: float, tol: float) -> float:
    """First time after which |v - target| stays within tol forever."""
    outside = np.abs(v - target) > tol
    if not outside.any():
        return 0.0
    last_outside = len(outside) - 1 - np.argmax(outside[::-1])
    if last_outside == len(outside) - 1:
        raise ValueError("voltage never settled; t_max too small")
    return float(t_ns[last_outside + 1])


class BitlineModel:
    """Computes TL-DRAM timing constraints for arbitrary segment lengths."""

    def __init__(self, params: CircuitParams | None = None):
        self.p = params or CircuitParams()

    # -- access classes ----------------------------------------------------

    def unsegmented(self, cells: int) -> SegmentTimings:
        """A conventional bitline with ``cells`` cells (no isolation FET)."""
        c = self.p.c_bl(cells)
        return self._solve(c_near=c, c_far=None, cell_on_far=False)

    def near(self, near_cells: int, far_cells: int | None = None) -> SegmentTimings:
        """Accessing a near-segment cell: isolation FET OFF, far floating.

        The far segment is electrically invisible apart from the iso FET's
        junction capacitance, so the latency matches a short bitline of
        ``near_cells`` cells (paper Sec. 3).
        """
        del far_cells  # disconnected: does not load the near segment
        c = self.p.c_bl(near_cells) + self.p.c_iso_junction_f
        return self._solve(c_near=c, c_far=None, cell_on_far=False)

    def far(self, near_cells: int, far_cells: int) -> SegmentTimings:
        """Accessing a far-segment cell: isolation FET ON (acts as a resistor)."""
        c_n = self.p.c_bl(near_cells) + self.p.c_iso_junction_f
        c_f = self.p.c_bl(far_cells)
        return self._solve(c_near=c_n, c_far=c_f, cell_on_far=True)

    def _solve(self, c_near: float, c_far: float | None,
               cell_on_far: bool) -> SegmentTimings:
        t_max = 100.0
        while True:
            # Scale dt with the window so the step count stays bounded; never
            # coarser than needed to resolve the fixed-overhead windows.
            dt = max(self.p.dt_ns, t_max / 40_000.0)
            try:
                act = _euler_activation(self.p, c_near=c_near, c_far=c_far,
                                        cell_on_far=cell_on_far, t_max_ns=t_max,
                                        dt_ns=dt)
                pre = _euler_precharge(self.p, c_near=c_near, c_far=c_far,
                                       t_max_ns=t_max, dt_ns=dt)
                return self._timings(act, pre)
            except ValueError:
                t_max *= 4.0
                if t_max > 2.0e6:
                    raise

    # -- waveforms for Figs. 6/7 -------------------------------------------

    def activation_waveform(self, near_cells: int, far_cells: int | None,
                            access_far: bool) -> BitlineWaveform:
        if far_cells is None or not access_far:
            cells = near_cells if far_cells is not None else near_cells
            c = self.p.c_bl(cells) + (self.p.c_iso_junction_f if far_cells is not None else 0.0)
            return _euler_activation(self.p, c_near=c, c_far=None, cell_on_far=False)
        c_n = self.p.c_bl(near_cells) + self.p.c_iso_junction_f
        return _euler_activation(self.p, c_near=c_n, c_far=self.p.c_bl(far_cells),
                                 cell_on_far=True)

    # -- internals -----------------------------------------------------------

    def _timings(self, act: BitlineWaveform, pre: BitlineWaveform) -> SegmentTimings:
        p = self.p
        thr = SENSE_THRESHOLD_FRAC * p.vdd
        restored = RESTORED_FRAC * p.vdd
        tol = PRECHARGE_TOL_FRAC * p.vdd
        v_half = 0.5 * p.vdd

        t_rcd = p.t_decode_ns + _first_crossing(act.t_ns, act.v_near, thr)

        # restored: every storage/bitline node back at VDD (cell is the slowest;
        # for far accesses the far bitline must also be restored).  The cell
        # starts at VDD and dips during charge sharing -> use "stays above".
        t_restore = _first_stays_above(act.t_ns, act.v_cell, restored)
        if act.v_far is not None:
            t_restore = max(t_restore, _first_stays_above(act.t_ns, act.v_far, restored))
        t_ras = p.t_decode_ns + t_restore

        t_rp = p.t_pre_fixed_ns + _first_settled(pre.t_ns, pre.v_near, v_half, tol)
        if pre.v_far is not None:
            t_rp = max(t_rp, p.t_pre_fixed_ns +
                       _first_settled(pre.t_ns, pre.v_far, v_half, tol))
        return SegmentTimings(t_rcd=t_rcd, t_ras=t_ras, t_rp=t_rp)


@functools.lru_cache(maxsize=512)
def _cached_timings(kind: str, a: int, b: int, params: CircuitParams) -> SegmentTimings:
    m = BitlineModel(params)
    if kind == "unsegmented":
        return m.unsegmented(a)
    if kind == "near":
        return m.near(a, b)
    if kind == "far":
        # `a` is the far-segment length, `b` the near-segment length.
        return m.far(near_cells=b, far_cells=a)
    raise ValueError(kind)


def timings(kind: str, cells: int, other_cells: int = 0,
            params: CircuitParams | None = None) -> SegmentTimings:
    """Cached convenience wrapper.

    kind='unsegmented': ``cells`` on one bitline.
    kind='near':  near segment of ``cells`` (far = ``other_cells``, floating).
    kind='far':   far segment of ``cells`` behind a near segment of ``other_cells``.
    """
    return _cached_timings(kind, cells, other_cells, params or CircuitParams())


# ---------------------------------------------------------------------------
# Calibration layer.
#
# The lumped-RC ODE reproduces the circuit *dynamics* (waveform shapes, the
# direction and relative size of every trend in Figs. 5-7), but a 3-node lumped
# model cannot also reproduce DRAM's large length-independent latency floor
# (regenerative SA latching, wordline RC trees, driver turn-on) without a
# full distributed model.  Following standard practice, the absolute timings
# are an affine map of the ODE solution, anchored to published values:
#
#   tRC  : Table 1 of the paper  (short-32 = 23.1 ns, long-512 = 52.5 ns)
#   tRCD : JEDEC DDR3-1066 7-7-7 (long-512 = 13.75 ns) and an RLDRAM-class
#          short-bitline part    (short-32 =  8.0 ns)
#   tRP  : DDR3 (13.125 ns) / short-bitline (7.0 ns)
#
# r_iso is then solved so the calibrated far-480 tRC hits Table 1's 65.8 ns.
# The affine coefficients below are produced by ``repro.core.calibrate``.
# ---------------------------------------------------------------------------

TRCD_ANCHORS_NS = {"short_32": 8.0, "long_512": 13.75}
TRP_ANCHORS_NS = {"short_32": 7.0, "long_512": 13.125}


@dataclass(frozen=True)
class AffineCal:
    """Affine calibration ``t_cal = a + b * t_ode`` per timing constraint."""

    a_rcd: float
    b_rcd: float
    a_rc: float
    b_rc: float
    a_rp: float
    b_rp: float


# Baked by `python -m repro.core.calibrate` (see that module).
DEFAULT_CAL: AffineCal = AffineCal(
    a_rcd=3.154494, b_rcd=0.922953,
    a_rc=10.109985, b_rc=0.733899,
    a_rp=5.501504, b_rp=0.272950,
)


def calibrated_timings(kind: str, cells: int, other_cells: int = 0,
                       params: CircuitParams | None = None,
                       cal: AffineCal | None = None) -> SegmentTimings:
    """ODE timings passed through the Table-1-anchored affine calibration."""
    cal = cal or DEFAULT_CAL
    if cal is None:
        raise RuntimeError("no calibration constants available")
    raw = timings(kind, cells, other_cells, params=params)
    t_rcd = cal.a_rcd + cal.b_rcd * raw.t_rcd
    t_rc = cal.a_rc + cal.b_rc * raw.t_rc
    t_rp = cal.a_rp + cal.b_rp * raw.t_rp
    return SegmentTimings(t_rcd=t_rcd, t_ras=t_rc - t_rp, t_rp=t_rp)


def table1_model(params: CircuitParams | None = None,
                 cal: AffineCal | None = None,
                 calibrated: bool = False) -> dict[str, SegmentTimings]:
    """The four Table-1 design points (raw ODE or calibrated)."""
    fn = (lambda k, c, o: calibrated_timings(k, c, o, params=params, cal=cal)) \
        if calibrated else (lambda k, c, o: timings(k, c, o, params=params))
    return {
        "short_32": fn("unsegmented", TABLE1_NEAR_CELLS, 0),
        "long_512": fn("unsegmented", CELLS_PER_BITLINE, 0),
        "near_32": fn("near", TABLE1_NEAR_CELLS, TABLE1_FAR_CELLS),
        "far_480": fn("far", TABLE1_FAR_CELLS, TABLE1_NEAR_CELLS),
    }


def segment_length_sweep(
    near_lengths: tuple[int, ...] = (16, 32, 64, 128, 256),
    total_cells: int = CELLS_PER_BITLINE,
    params: CircuitParams | None = None,
    calibrated: bool = True,
) -> dict[str, dict[int, SegmentTimings]]:
    """Fig. 5: near/far latencies as a function of the split point."""
    fn = (lambda k, c, o: calibrated_timings(k, c, o, params=params)) if calibrated \
        else (lambda k, c, o: timings(k, c, o, params=params))
    near = {n: fn("near", n, total_cells - n) for n in near_lengths}
    far = {total_cells - n: fn("far", total_cells - n, n) for n in near_lengths}
    return {"near": near, "far": far}


def with_params(**overrides) -> CircuitParams:
    return dataclasses.replace(CircuitParams(), **overrides)
