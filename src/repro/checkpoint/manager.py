"""Fault-tolerant checkpoint manager.

Properties required at 1000-node scale:
  * atomic commits: writes go to ``step_N.tmp/`` and rename to ``step_N/``
    only after every shard + the manifest fsyncs — a crash mid-save never
    corrupts the latest checkpoint;
  * integrity: every array file carries a crc32 recorded in the manifest and
    verified on restore;
  * async save: serialization happens on a background thread from a snapshot
    (jax.device_get) so the train loop is blocked only for the copy;
  * data-iterator state is saved with the model (exact resume);
  * retention: keep the newest K checkpoints, never deleting an unverified
    successor's predecessor.

Multi-host: each process writes its own addressable shards under
``shard_<process_index>/`` and process 0 commits the manifest after a
barrier; on this single-process container that degenerates to one shard dir
(the layout is identical, asserted in tests).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
import zlib
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, extra: dict | None = None,
             blocking: bool = True) -> None:
        """Snapshot now; serialize now (blocking) or on a worker thread."""
        self.wait()  # one outstanding async save at a time
        snapshot = jax.device_get(tree)
        if blocking:
            self._write(step, snapshot, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, snapshot, extra or {}),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_guarded(self, step, snapshot, extra):
        try:
            self._write(step, snapshot, extra)
        except BaseException as e:  # noqa: BLE001 — surfaced on wait()
            self._error = e

    def _write(self, step: int, snapshot, extra: dict) -> None:
        leaves, treedef = _flatten(snapshot)
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        shard_dir = tmp / f"shard_{jax.process_index():05d}"
        shard_dir.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            path = shard_dir / f"leaf_{i:05d}.npy"
            with open(path, "wb") as f:
                np.save(f, arr)
                f.flush()
            manifest["leaves"].append({
                "index": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()),
                "file": str(path.relative_to(tmp)),
            })
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
        tmp.rename(final)          # the atomic commit point
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore -------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            if not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None):
        """Restore into the structure of ``tree_like``.

        Returns (tree, extra).  Verifies every leaf's crc32; a corrupted
        checkpoint raises and the caller may retry with an older step.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        root = self.dir / f"step_{step:010d}"
        manifest = json.loads((root / "manifest.json").read_text())
        leaves_like, treedef = _flatten(tree_like)
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"expected {len(leaves_like)}")
        leaves = []
        for rec in manifest["leaves"]:
            arr = np.load(root / rec["file"])
            if zlib.crc32(arr.tobytes()) != rec["crc32"]:
                raise IOError(f"crc mismatch in {rec['file']} @ step {step}")
            leaves.append(arr)
        return treedef.unflatten(leaves), manifest["extra"]

    def restore_with_fallback(self, tree_like):
        """Walk checkpoints newest-to-oldest until one verifies (the
        node-failure recovery path)."""
        last_err: Exception | None = None
        for step in reversed(self.all_steps()):
            try:
                return self.restore(tree_like, step)
            except Exception as e:  # noqa: BLE001
                last_err = e
        raise last_err or FileNotFoundError("no restorable checkpoint")
