"""Pallas decode-attention kernel over the TL-DRAM-style *near tier*.

The TPU adaptation of the paper's near segment: hot KV pages live in a small
*contiguous* buffer (near tier) that this kernel streams HBM->VMEM with dense
BlockSpec tiles — sequential DMA at full bandwidth, the TPU analogue of the
short bitline's low latency.  Cold pages stay in the paged far tier and are
attended by the XLA gather path; the two partial results are merged with the
standard log-sum-exp composition (``ops.tiered_decode_attention``).

The kernel returns *unnormalized* (out, m, l) online-softmax statistics so
the merge is exact.

Grid: (batch, kv_heads).  Per step: this head's query group (g, hd) and the
near-tier panel (T_near, hd) are VMEM-resident; K/V stream in block_kv tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _near_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, *,
                        block_kv: int, t_near: int, scale: float):
    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale           # (g, hd)
    g, hd = q.shape
    length = len_ref[0]                                          # scalar int32

    n_kv = t_near // block_kv

    def body(i, carry):
        acc, m, l = carry
        k = k_ref[0, pl.ds(i * block_kv, block_kv), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * block_kv, block_kv), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bkv)
        slot = i * block_kv + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)
        s = jnp.where(slot < length, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(slot < length, p, 0.0)
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return acc_new, m_new, l_new

    acc = jnp.zeros((g, hd), jnp.float32)
    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_kv, body, (acc, m, l))
    o_ref[0, 0, :, :] = acc
    m_ref[0, 0, :] = m[:, 0]
    l_ref[0, 0, :] = l[:, 0]


def _block_geometry(t_near: int, block_kv: int) -> tuple[int, int]:
    """(block_kv, padded T) for a near buffer of ``t_near`` tokens.

    The buffer is *padded up* to a block multiple rather than the block
    shrunk to a divisor: halving until ``block_kv`` divides ``t_near``
    degenerates to 1-2 token blocks whenever ``t_near`` has a large odd
    factor (e.g. 130 -> block 2), destroying kernel throughput.  Padded
    slots sit at indices >= t_near >= near_len, so the kernel's
    ``near_len`` mask already excludes them.
    """
    if t_near <= block_kv:
        return t_near, t_near            # single block, no padding
    pad = (-t_near) % block_kv
    return block_kv, t_near + pad


def near_decode_attention(q: jax.Array, k_near: jax.Array, v_near: jax.Array,
                          near_len: jax.Array, block_kv: int = 128,
                          interpret: bool = False):
    """Flash-decode over the contiguous near tier.

    q: (B, H, hd) single-token queries; k_near/v_near: (B, T_near, Hkv, hd);
    near_len: (B,) int32 — live entries per sequence.

    Returns (out (B,H,hd) f32 unnormalized, m (B,H) f32, l (B,H) f32).
    """
    B, H, hd = q.shape
    T, Hkv = k_near.shape[1], k_near.shape[2]
    g = H // Hkv
    block_kv, T = _block_geometry(T, block_kv)
    if T > k_near.shape[1]:
        pad = ((0, 0), (0, T - k_near.shape[1]), (0, 0), (0, 0))
        k_near = jnp.pad(k_near, pad)
        v_near = jnp.pad(v_near, pad)
    q4 = q.reshape(B, Hkv, g, hd)

    kernel = functools.partial(_near_decode_kernel, block_kv=block_kv,
                               t_near=T, scale=hd ** -0.5)
    out, m, l = pl.pallas_call(
        kernel,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
        ],
        interpret=interpret,
    )(q4, k_near, v_near, near_len)
    return (out.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H))
