"""Llama-4 Scout: 17B-active MoE with 16 experts, top-1 routing + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1, early fusion.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    moe=MoEConfig(n_experts=16, top_k=1, d_expert=8192, n_shared_experts=1),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
