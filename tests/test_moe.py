"""MoE dispatch tests: einsum vs gather implementations, capacity semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe as M


@pytest.fixture
def setup():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared_experts=1)
    params = M.init_moe_params(jax.random.key(0), 64, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 48, 64), jnp.float32) * 0.5
    return cfg, params, x


class TestImplEquivalence:
    def test_gather_matches_einsum(self, setup):
        cfg, params, x = setup
        y1, a1 = M.moe_block(params, x, cfg, group_size=32, impl="einsum")
        y2, a2 = M.moe_block(params, x, cfg, group_size=32, impl="gather")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-5, atol=2e-5)
        assert float(a1) == pytest.approx(float(a2))

    def test_no_drop_paths_match(self, setup):
        cfg, params, x = setup
        y1, _ = M.moe_block(params, x, cfg, group_size=32, impl="einsum",
                            no_drop=True)
        y2, _ = M.moe_block(params, x, cfg, group_size=32, impl="gather",
                            no_drop=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("impl", ["einsum", "gather"])
    def test_grads_flow(self, setup, impl):
        cfg, params, x = setup

        def loss(p):
            y, aux = M.moe_block(p, x, cfg, group_size=32, impl=impl)
            return jnp.sum(y ** 2) + 0.01 * aux

        g = jax.grad(loss)(params)
        total = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
        assert np.isfinite(total) and total > 0


class TestCapacitySemantics:
    def test_no_drop_capacity_is_lossless(self, setup):
        """With no_drop, every token's weighted expert mix is applied: the
        output must differ from zero for all tokens even under adversarial
        routing (all tokens to one expert)."""
        cfg, params, x = setup
        # bias the router so everything lands on expert 0
        params = dict(params)
        params["router"] = params["router"].at[:, 0].add(100.0)
        y, _ = M.moe_block(params, x, cfg, group_size=16, impl="einsum",
                           no_drop=True)
        norms = jnp.linalg.norm(y.reshape(-1, y.shape[-1]), axis=-1)
        assert float(norms.min()) > 0

    def test_capacity_drops_under_hot_expert(self, setup):
        """With the standard capacity factor, adversarial routing drops
        tokens (they fall back to the shared expert only)."""
        cfg, params, x = setup
        params = dict(params)
        params["router"] = params["router"].at[:, 0].add(100.0)
        y_cap, _ = M.moe_block(params, x, cfg, group_size=16, impl="einsum")
        y_free, _ = M.moe_block(params, x, cfg, group_size=16, impl="einsum",
                                no_drop=True)
        assert not np.allclose(np.asarray(y_cap), np.asarray(y_free))

    def test_weights_renormalized(self, setup):
        """Top-k weights sum to 1 before capacity masking."""
        cfg, params, x = setup
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                            params["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        w, _ = jax.lax.top_k(probs, cfg.top_k)
        w = w / w.sum(-1, keepdims=True)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
