"""Fused page-table-walking serving read path (ISSUE 4 tentpole).

Pins, end to end through the serving engine:

  (1) token parity — emitted tokens are bit-identical between the fused
      kernel path and the dense oracle path across SC/WMC/BBC/STATIC
      (fast legs here, the full policy x trace matrix under @slow);
  (2) far-rows accounting — the fused path's far rows touched per step
      equal the sum of live, non-promoted page rows (device walk metadata
      vs an independent host shadow), never ``n_pages * page * B``;
  (3) metadata hoisting — the per-step read metadata is computed ONCE per
      decode step (call-count pin) and nothing ``(B, n_pages, C)``-shaped
      survives in the per-layer trace (jaxpr pin) — the equality tensor
      ``_paged_masks`` used to rebuild per layer is gone.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core import tiered_kv as tkv
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer
from repro.serve import ServingConfig, ServingEngine
from repro.serve.trace import Request, SCENARIOS

POLICIES = ("SC", "WMC", "BBC", "STATIC")


def _arch_params(seed=0):
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(seed), arch)
    return arch, params


def _trace(vocab, rng, n=5):
    lens = [20, 12, 20, 12, 20]
    arrivals = [0, 1, 3, 6, 10]
    return [Request(rid=i, arrival=arrivals[i],
                    prompt=rng.integers(0, vocab, lens[i]).astype(np.int32),
                    max_new_tokens=8)
            for i in range(n)]


def _config(policy, fused, share=False, **kw):
    tier = TieredKVConfig(page=16, near_pages=2, interval=3, policy=policy,
                          fused_kernel=fused)
    return ServingConfig(n_slots=3, max_len=64, prefill_bucket=16, tier=tier,
                         share_prefix=share, **kw)


class TestFusedTokenParity:
    @pytest.mark.parametrize("policy", ["BBC", "STATIC"])
    def test_fused_equals_dense_tokens(self, policy):
        """The fused walk changes which bytes move, never the tokens."""
        arch, params = _arch_params()
        trace = _trace(arch.vocab, np.random.default_rng(7))
        dense = ServingEngine(params, arch,
                              _config(policy, False)).run(trace, "t")
        fused = ServingEngine(
            params, arch,
            _config(policy, True, verify_tiered_read=True)).run(trace, "t")
        assert dense.outputs == fused.outputs
        # the read-path probe in fused mode exercises the kernel itself
        assert fused.max_read_err < 5e-2

    def test_fused_with_prefix_sharing_equals_dense(self):
        """Shared pool pages + global near tier + fused walk: still the
        same tokens (shared promoted pages served near for every tenant)."""
        arch, params = _arch_params(seed=1)
        trace = SCENARIOS["shared_system_prompt"](
            arch.vocab, n_requests=6, sys_len=32, user_len=12,
            max_new_tokens=8, gap=2)
        dense = ServingEngine(params, arch,
                              _config("BBC", False, share=True)
                              ).run(trace, "t")
        fused = ServingEngine(params, arch,
                              _config("BBC", True, share=True)
                              ).run(trace, "t")
        assert dense.outputs == fused.outputs
        assert fused.prefix_hit_tokens > 0, "sharing never hit"

    @pytest.mark.slow
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("scenario", ["steady_zipfian", "bursty"])
    def test_full_policy_matrix_token_identical(self, policy, scenario):
        """ISSUE 4 acceptance: bit-identical emitted tokens across
        SC/WMC/BBC/STATIC between fused and dense on the serving traces."""
        arch, params = _arch_params(seed=2)
        trace = SCENARIOS[scenario](arch.vocab, n_requests=8, prompt_len=20,
                                    max_new_tokens=10, gap=1) \
            if scenario == "steady_zipfian" else \
            SCENARIOS[scenario](arch.vocab, n_requests=8, prompt_len=20,
                                max_new_tokens=10, burst=4, burst_gap=12)
        dense = ServingEngine(params, arch,
                              _config(policy, False)).run(trace, scenario)
        fused = ServingEngine(params, arch,
                              _config(policy, True)).run(trace, scenario)
        assert dense.outputs == fused.outputs, \
            f"{policy}/{scenario}: fused path changed emitted tokens"


class TestFarRowsAccounting:
    def test_fused_touches_live_nonpromoted_rows_only(self):
        """ISSUE 4 acceptance: per-step far rows touched == sum of live,
        non-promoted page rows — two independent accountings (device walk
        metadata vs host shadow) agree, and both beat n_pages*page*B."""
        arch, params = _arch_params()
        trace = _trace(arch.vocab, np.random.default_rng(7))
        rep = ServingEngine(params, arch,
                            _config("BBC", True)).run(trace, "t")
        assert rep.far_rows_touched > 0
        assert rep.far_rows_touched == rep.far_rows_host, \
            "device walk accounting diverges from the host shadow"
        assert rep.far_rows_touched < rep.far_rows_dense, \
            "fused path touched as many far rows as the materializing path"
        assert rep.far_rows_saved_frac > 0.5

    def test_dense_mode_accounts_full_far_view(self):
        arch, params = _arch_params()
        trace = _trace(arch.vocab, np.random.default_rng(7))
        rep = ServingEngine(params, arch,
                            _config("BBC", False)).run(trace, "t")
        assert rep.far_rows_touched == rep.far_rows_dense
        assert rep.far_rows_saved_frac == 0.0


class TestMetadataHoisting:
    def test_step_metadata_computed_once_per_decode_step(self):
        """The read metadata depends only on (page_table, slot_of_page,
        page_of_slot, pos): one computation per tick, shared by all layers
        (it used to be rebuilt per layer as a (B, n_pages, C) tensor)."""
        arch, params = _arch_params()
        trace = _trace(arch.vocab, np.random.default_rng(7))
        eng = ServingEngine(params, arch, _config("BBC", True))
        calls = []
        orig = eng._meta
        eng._meta = lambda *a: (calls.append(1), orig(*a))[1]
        rep = eng.run(trace, "t")
        assert len(calls) == rep.steps, \
            f"metadata computed {len(calls)}x for {rep.steps} decode steps"

    def test_no_b_npages_c_intermediate_in_per_layer_trace(self):
        """jaxpr pin: with distinctive (B, n_pages, C) = (5, 7, 3), no
        intermediate of that shape may appear anywhere in the fused decode
        step OR in the dense read path (both now derive masks from the
        hoisted scatter-built metadata).  Routed through the shared
        ``repro.analysis`` walker (the old private ``_shapes_in`` helper);
        ``python -m repro.analysis`` additionally enforces the same ban
        over the whole target registry (no-dense-far-view pass)."""
        from repro.analysis import intermediate_shapes
        arch, params = _arch_params()
        B, n_pages, C, page = 5, 7, 3, 8
        P = B * n_pages + 2
        tier = TieredKVConfig(page=page, near_pages=C, fused_kernel=True)
        paged = tkv.init_paged_cache(tier, B, n_pages, P, arch.n_kv_heads,
                                     arch.resolved_head_dim)
        pos = jnp.full((B,), 2 * page + 3, jnp.int32)
        q = jnp.zeros((B, arch.n_heads, arch.resolved_head_dim), jnp.float32)

        bad = (B, n_pages, C)
        # (a) the dense oracle read (meta computed inside)
        dense_tier = TieredKVConfig(page=page, near_pages=C)
        jx = jax.make_jaxpr(
            lambda c, q, p: tkv.paged_tiered_attention(c, q, p, dense_tier)
        )(paged, q, pos)
        shapes = intermediate_shapes(jx)
        assert bad not in shapes, \
            f"dense read path still builds a {bad} equality tensor"

        # (b) the fused per-layer decode trace, meta precomputed per step.
        # The cache is pool-only (ISSUE 5): no dense per-slot k/v leaves
        # exist anywhere in the paged serving path.
        cache = {
            "pos": pos,
            "pool_k": jnp.zeros(
                (arch.n_layers, P, page, arch.n_kv_heads,
                 arch.resolved_head_dim), jnp.bfloat16),
            "near_k": jnp.zeros(
                (arch.n_layers, C * page, arch.n_kv_heads,
                 arch.resolved_head_dim), jnp.bfloat16),
        }
        cache["pool_v"] = cache["pool_k"]
        cache["near_v"] = cache["near_k"]
        meta = tkv.paged_step_metadata(paged, pos + 1, tier, append_pos=pos)
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
        jx2 = jax.make_jaxpr(
            lambda c, b, m: transformer.paged_decode_step(
                params, c, b, arch, m))(cache, batch, meta)
        shapes2 = intermediate_shapes(jx2)
        assert bad not in shapes2, \
            f"per-layer fused trace contains a {bad} intermediate"
        # the metadata itself enters the trace — as small 2-D inputs
        in_shapes = {tuple(v.aval.shape) for v in jx2.jaxpr.invars}
        assert (B, n_pages) in in_shapes
