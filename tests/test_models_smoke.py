"""Per-architecture smoke tests: reduced configs, one forward / train / decode
step on CPU, asserting output shapes and no NaNs.  The FULL configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import ARCHS
from repro.models import model_zoo, transformer

SMOKE_SHAPE = InputShape("smoke", seq_len=64, global_batch=2, kind="train")

ARCH_IDS = sorted(ARCHS)


@pytest.fixture(scope="module")
def built():
    """Cache reduced params per arch across tests."""
    cache = {}

    def get(name):
        if name not in cache:
            arch = ARCHS[name].reduced()
            params = transformer.init_params(jax.random.key(0), arch)
            cache[name] = (arch, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_finite(name, built):
    arch, params = built(name)
    batch = model_zoo.make_batch(arch, SMOKE_SHAPE)
    logits, aux = transformer.forward(params, batch, arch)
    B, S = 2, 64
    if arch.family == "audio":
        assert logits.shape == (B, S, arch.n_codebooks, arch.vocab)
    else:
        assert logits.shape == (B, S, arch.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step_reduces_loss(name, built):
    arch, params = built(name)
    batch = model_zoo.make_batch(arch, SMOKE_SHAPE)

    @jax.jit
    def step(p):
        (loss, metrics), grads = jax.value_and_grad(
            transformer.loss_fn, has_aux=True)(p, batch, arch)
        p = jax.tree.map(lambda a, g: a - 0.5 * g.astype(a.dtype), p, grads)
        return p, loss

    p, loss0 = step(params)
    assert np.isfinite(float(loss0))
    for _ in range(3):
        p, loss = step(p)
    assert float(loss) < float(loss0), "SGD on one batch must reduce loss"


@pytest.mark.parametrize("name", ARCH_IDS)
def test_grads_finite_and_nonzero(name, built):
    arch, params = built(name)
    batch = model_zoo.make_batch(arch, SMOKE_SHAPE)
    grads = jax.grad(lambda p: transformer.loss_fn(p, batch, arch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0.0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_then_decode_matches_forward(name, built, monkeypatch):
    """Decode path correctness: prefill(S) + decode(1) logits must match the
    full forward at the corresponding positions.

    MoE runs lossless (no capacity drops): with capacity enabled, dropping
    is batch-composition-dependent, so prefill+decode and the monolithic
    forward can legitimately route borderline tokens differently."""
    from repro.models import moe as moe_lib
    monkeypatch.setattr(moe_lib, "DEFAULT_NO_DROP", True)
    arch, params = built(name)
    S, B = 32, 2
    shape = InputShape("s", seq_len=S, global_batch=B, kind="prefill")
    batch = model_zoo.make_batch(arch, shape, compute_dtype=jnp.float32)

    logits_full, _ = transformer.forward(params, batch, arch,
                                         compute_dtype=jnp.float32)
    logits_pre, cache = transformer.prefill(params, batch, arch, max_len=S + 8,
                                            compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_pre, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)

    # decode one token; compare against forward over the extended sequence
    if arch.family == "audio":
        step_batch = {"frame_embeds": batch["frame_embeds"][:, :1]}
        ext = {"frame_embeds": jnp.concatenate(
            [batch["frame_embeds"], step_batch["frame_embeds"]], axis=1)}
    else:
        step_batch = {"tokens": batch["tokens"][:, :1]}
        ext = {"tokens": jnp.concatenate(
            [batch["tokens"], step_batch["tokens"]], axis=1)}
        if arch.family == "vlm":
            step_batch["positions"] = None  # decode derives positions from pos
            step_batch.pop("positions")
            ext["patch_embeds"] = batch["patch_embeds"]
            B_, S_ = ext["tokens"].shape
            pos = np.broadcast_to(np.arange(S_, dtype=np.int32)[None, :, None],
                                  (B_, S_, 3))
            ext["positions"] = jnp.asarray(pos)

    logits_dec, cache2 = transformer.decode_step(params, cache, step_batch,
                                                 arch,
                                                 compute_dtype=jnp.float32)
    logits_ext, _ = transformer.forward(params, ext, arch,
                                        compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0], np.float32),
                               np.asarray(logits_ext[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("name", ["hymba-1.5b", "mamba2-1.3b"])
def test_long_context_archs_have_bounded_decode_state(name):
    """The sub-quadratic archs must not allocate O(seq) KV for huge contexts
    beyond their window (hymba) or at all (mamba2)."""
    arch = ARCHS[name].reduced()
    cache = transformer.init_cache(arch, batch=1, max_len=100_000)
    if name == "mamba2-1.3b":
        assert "k" not in cache
    else:
        assert cache["k"].shape[2] == arch.sliding_window  # ring buffer
    total_bytes = sum(np.prod(v.shape) * v.dtype.itemsize
                      for v in jax.tree.leaves(cache))
    assert total_bytes < 50e6


def test_param_counts_sane():
    """Analytical param counts should be in the advertised ballpark."""
    expected = {
        "kimi-k2-1t-a32b": (0.9e12, 1.3e12),
        "llama4-scout-17b-a16e": (0.9e11, 1.3e11),  # 16 experts full size
        "deepseek-coder-33b": (30e9, 36e9),
        "yi-9b": (8e9, 10e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "starcoder2-3b": (2.7e9, 3.6e9),
        "mamba2-1.3b": (1.1e9, 1.6e9),
        "hymba-1.5b": (1.2e9, 1.9e9),
        "qwen2-vl-2b": (1.2e9, 2.3e9),
        "musicgen-medium": (1.3e9, 2.2e9),
    }
    for name, (lo, hi) in expected.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    k2 = ARCHS["kimi-k2-1t-a32b"]
    active = k2.active_param_count()
    assert 25e9 <= active <= 45e9, f"K2 active {active/1e9:.1f}B"
