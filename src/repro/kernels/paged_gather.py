"""Pallas kernel: page-granular KV gather from the shared far pool.

The paged far tier (docs/design.md §2d) keeps one refcounted pool of KV
pages; each slot's far view is its page table resolved against the pool.
XLA lowers that resolution to a row gather — fine, but grain-agnostic.  This
kernel exploits the page structure: the unit of transfer is a whole
(page, Hkv*hd) panel, so each grid step issues ONE dynamic VMEM load per
page instead of per-row gathers — the TL-DRAM observation that the far
segment's cost is per-activation, not per-bit, applied to the gather path.

Grid: (B, n_pages).  VMEM per step: the full pool (production note: block
the pool once P*page*D exceeds VMEM) plus one output page panel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paged_gather_kernel(ids_ref, pool_ref, o_ref):
    pid = ids_ref[0, 0]
    panel = pool_ref[pl.ds(jnp.maximum(pid, 0), 1), :, :]        # (1,page,D)
    o_ref[0, :, :] = jnp.where(pid >= 0, panel[0], 0.0).astype(o_ref.dtype)


def paged_gather(pool: jax.Array, page_ids: jax.Array,
                 interpret: bool = False) -> jax.Array:
    """pool: (P, page, Hkv, hd); page_ids: (B, n_pages) int32 (< 0 => zeros).

    Returns (B, n_pages*page, Hkv, hd): each row b is the contiguous
    materialization of b's page table against the pool."""
    P, page, Hkv, hd = pool.shape
    B, n_pages = page_ids.shape
    D = Hkv * hd
    pool2 = pool.reshape(P, page, D)

    out = pl.pallas_call(
        functools.partial(_paged_gather_kernel),
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, j)),
            pl.BlockSpec((P, page, D), lambda b, j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page, D), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_pages * page, D), pool.dtype),
        interpret=interpret,
    )(page_ids, pool2)
    return out.reshape(B, n_pages * page, Hkv, hd)
