"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(``tests/test_kernels_*.py`` sweep shapes/dtypes and assert_allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """Materialized softmax attention.  q: (B,S,H,hd); k,v: (B,T,Hkv,hd)."""
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos + (T - S) >= kpos     # aligned to the sequence end
    if window:
        mask &= qpos + (T - S) - kpos < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array) -> jax.Array:
    """Single-token decode attention with a live-prefix mask.

    q: (B,1,H,hd); k,v: (B,T,Hkv,hd); length: (B,) valid prefix per sequence.
    """
    B, _, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    live = jnp.arange(T)[None, :] < length[:, None]          # (B,T)
    scores = jnp.where(live[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v).astype(q.dtype)


def decode_attention_stats_ref(q, k, v, length):
    """Like decode_attention_ref but returns (unnormalized_out, m, l) online-
    softmax statistics, for two-tier merging."""
    B, _, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)[:, :, 0]
    scores = scores * (hd ** -0.5)                           # (B,H,T)
    live = jnp.arange(T)[None, None, :] < length[:, None, None]
    scores = jnp.where(live, scores, NEG_INF)
    m = scores.max(axis=-1)                                  # (B,H)
    p = jnp.exp(scores - m[..., None]) * live
    l = p.sum(axis=-1)                                       # (B,H)
    out = jnp.einsum("bht,bthd->bhd", p.astype(v.dtype), v)  # unnormalized
    return out.astype(jnp.float32), m, l


def merge_attention_stats(parts):
    """Log-sum-exp merge of (out, m, l) partial attention results."""
    outs, ms, ls = zip(*parts)
    m = jnp.stack(ms).max(axis=0)
    total_out = sum(o * jnp.exp(mi - m)[..., None] for o, mi in zip(outs, ms))
    total_l = sum(li * jnp.exp(mi - m) for li, mi in zip(ls, ms))
    return total_out / jnp.maximum(total_l, 1e-30)[..., None]


def tiered_gather_ref(near_table: jax.Array, near_slots: jax.Array,
                      far_values: jax.Array) -> jax.Array:
    """out[t] = near_table[near_slots[t]] if near_slots[t] >= 0 else far_values[t].

    near_table: (C,D); near_slots: (T,) int32 (-1 => far); far_values: (T,D).
    """
    gathered = jnp.take(near_table, jnp.maximum(near_slots, 0), axis=0)
    return jnp.where((near_slots >= 0)[:, None], gathered, far_values)


def paged_gather_ref(pool: jax.Array, page_ids: jax.Array) -> jax.Array:
    """out[b, j*page:(j+1)*page] = pool[page_ids[b, j]], zeros where id < 0.

    pool: (P, page, Hkv, hd); page_ids: (B, n_pages) int32.
    """
    B, n_pages = page_ids.shape
    _, page, Hkv, hd = pool.shape
    gathered = jnp.take(pool, jnp.maximum(page_ids, 0), axis=0)
    gathered = jnp.where((page_ids >= 0)[:, :, None, None, None], gathered,
                         jnp.zeros((), pool.dtype))
    return gathered.reshape(B, n_pages * page, Hkv, hd)


def paged_attention_ref(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                        near_k: jax.Array, near_v: jax.Array,
                        walk_pid: jax.Array, walk_live: jax.Array,
                        walk_len: jax.Array, near_live: jax.Array):
    """Semantic ground truth for ``kernels.paged_attention``: materialized
    softmax over the union of (near panels under per-slot live counts) and
    (walked far pages under partial-page live counts).

    q: (B,H,hd); pool: (P,page,Hkv,hd); near: (C*page,Hkv,hd);
    walk_pid/walk_live: (B,W); walk_len: (B,); near_live: (B,C).
    Returns unnormalized (out, m, l) stats like the kernel.
    """
    B, H, hd = q.shape
    P, page, Hkv, _ = pool_k.shape
    g = H // Hkv
    C = near_k.shape[0] // page
    W = walk_pid.shape[1]

    # far: gather the walked pages densely, then mask dead rows/entries
    k_far = jnp.take(pool_k, walk_pid, axis=0)        # (B, W, page, Hkv, hd)
    v_far = jnp.take(pool_v, walk_pid, axis=0)
    walked = jnp.arange(W)[None, :] < walk_len[:, None]            # (B, W)
    live_f = (jnp.arange(page)[None, None, :] < walk_live[:, :, None]) \
        & walked[:, :, None]                                       # (B,W,page)
    k_far = k_far.reshape(B, W * page, Hkv, hd)
    v_far = v_far.reshape(B, W * page, Hkv, hd)
    live_f = live_f.reshape(B, W * page)

    # near: broadcast the shared buffer, mask per-(slot, near-slot) counts
    k_near = jnp.broadcast_to(near_k[None], (B,) + near_k.shape)
    v_near = jnp.broadcast_to(near_v[None], (B,) + near_v.shape)
    live_n = (jnp.arange(page)[None, None, :]
              < near_live[:, :, None]).reshape(B, C * page)

    k = jnp.concatenate([k_near, k_far], axis=1)
    v = jnp.concatenate([v_near, v_far], axis=1)
    live = jnp.concatenate([live_n, live_f], axis=1)               # (B, T)

    qh = q.reshape(B, Hkv, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgd,btkd->bkgt", qh,
                   k.astype(jnp.float32))
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None]) * live[:, None, None, :]
    l = p.sum(axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return (out.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H))


def ssd_chunk_scan_ref(states: jax.Array, decays: jax.Array,
                       h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Inter-chunk SSD state recurrence.

    states: (nc,H,P,N) per-chunk accumulated inputs; decays: (nc,H) chunk-level
    decay; h0: (H,P,N).  Returns (h_prev (nc,H,P,N) — state *entering* each
    chunk — and the final state).
    """
    def body(h, inp):
        st, dec = inp
        return h * dec[:, None, None] + st, h

    h_final, h_prev = jax.lax.scan(body, h0, (states, decays))
    return h_prev, h_final
