"""Auto-divisible sharding rules: param/input/cache PartitionSpecs per arch.

Policy (docs/design.md Sec. 5):
  * TP ('model' axis): attention heads, FFN hidden, expert dim (EP), vocab.
  * DP/FSDP ('pod','data' axes): batch; optionally every parameter's d_model
    dim + optimizer moments (ZeRO-3-style, XLA inserts the per-layer
    all-gathers from the shardings).
  * Every rule is guarded by divisibility: a dim shards over an axis only if
    evenly divisible, otherwise the next candidate dim is tried (e.g. GQA
    with kv_heads < model axis shards head_dim instead), else replicates.

The rules are path-based over the parameter pytree, so they apply uniformly
to params, gradients, and (f32) optimizer moments.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        if a not in mesh.shape:
            return False
        size *= mesh.shape[a]
    return n % size == 0


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _pick(mesh: Mesh, dims: dict[int, int], *candidates):
    """candidates: (dim_index, axis) tried in order; returns {dim: axis}."""
    taken: dict[int, Any] = {}
    used_axes: set = set()
    for dim, axis in candidates:
        key = axis if isinstance(axis, tuple) else (axis,)
        if dim in taken or any(a in used_axes for a in key):
            continue
        if _div(dims[dim], mesh, axis):
            taken[dim] = axis
            used_axes.update(key)
    return taken


def _spec(ndim: int, placed: dict[int, Any]) -> P:
    return P(*[placed.get(i) for i in range(ndim)])


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def attn_heads_shardable(arch: ArchConfig, mesh: Mesh) -> bool:
    """TP attention only when Q heads divide the model axis; otherwise the
    attention weights replicate across 'model' (FSDP still shards them) and
    the MLP/vocab carry the tensor parallelism.  Avoids GSPMD involuntary
    rematerialization from mixed head/head_dim shardings."""
    return arch.n_heads > 0 and arch.n_heads % _model_size(mesh) == 0


def kv_heads_shardable(arch: ArchConfig, mesh: Mesh) -> bool:
    return (attn_heads_shardable(arch, mesh)
            and arch.n_kv_heads % _model_size(mesh) == 0)


def kv_shard_count(mesh, n_kv_heads: int) -> int:
    """How many ways the paged KV head dim actually shards: the 'model'
    axis size when it divides ``n_kv_heads``, else 1 — the GQA/MQA
    replication fallback (e.g. qwen2_vl_2b Hkv=2 or MQA Hkv=1 on a 4-way
    mesh keeps the pool replicated and the read path single-device-exact
    by construction).  ``mesh=None`` (the default single-device serving
    config) is 1."""
    if mesh is None:
        return 1
    m = mesh.shape.get("model", 1)
    return m if (m > 1 and n_kv_heads % m == 0) else 1


def ssm_heads_shardable(arch: ArchConfig, mesh: Mesh) -> bool:
    """SSD shards head-aligned: d_inner splits over 'model' only when whole
    heads land on each shard (mamba2: 64 heads over 16 ✓; hymba: 25 ✗)."""
    return (arch.ssm is not None
            and arch.ssm.n_heads % _model_size(mesh) == 0)


def param_specs(param_shapes, arch: ArchConfig, mesh: Mesh,
                fsdp: bool = True, dp_override: tuple[str, ...] | None = None):
    """PartitionSpec pytree matching ``param_shapes`` (shapes or arrays)."""
    dp = (dp_override if dp_override is not None else dp_axes(mesh)) \
        if fsdp else None
    heads_ok = attn_heads_shardable(arch, mesh)
    kv_ok = kv_heads_shardable(arch, mesh)
    ssm_ok = ssm_heads_shardable(arch, mesh)

    def rule(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        shape = leaf.shape
        dims = dict(enumerate(shape))
        nd = len(shape)

        def pick(*cands):
            return _spec(nd, _pick(mesh, dims, *cands))

        # Embedding/head shard the vocab only: feature-sharding the table
        # makes the token gather propagate a batch-replicated layout into
        # the whole network (observed via GSPMD involuntary-remat warnings).
        if name.endswith("embed"):                      # (V, D)
            return pick((0, "model"), (0, dp))
        if name.endswith("lm_head"):
            if nd == 3:                                 # (K, D, V) audio
                return pick((2, "model"), (2, dp))
            return pick((1, "model"), (1, dp))          # (D, V)
        if "attn" in name:
            if name.endswith("wq"):                     # (L, D, H, hd)
                return pick((2, "model"), (1, dp)) if heads_ok \
                    else pick((1, dp))
            if name.endswith(("wk", "wv")):             # (L, D, Hkv, hd)
                return pick((2, "model"), (1, dp)) if kv_ok \
                    else pick((1, dp))
            if name.endswith("wo"):                     # (L, H, hd, D)
                return pick((1, "model"), (3, dp)) if heads_ok \
                    else pick((3, dp))
            return P()                                   # qk norms
        if "moe" in name:
            if name.endswith("router"):                 # (L, D, E)
                return pick((1, dp))
            if "shared" in name:
                if name.endswith(("w_gate", "w_up")):   # (L, D, F)
                    return pick((2, "model"), (1, dp))
                return pick((1, "model"), (2, dp))      # (L, F, D)
            if name.endswith(("w_gate", "w_up")):       # (L, E, D, F)
                return pick((1, "model"), (2, dp))
            if name.endswith("w_down"):                 # (L, E, F, D)
                return pick((1, "model"), (3, dp))
        if "mlp" in name:
            if name.endswith(("w_gate", "w_up")):       # (L, D, F)
                return pick((2, "model"), (1, dp))
            if name.endswith("w_down"):                 # (L, F, D)
                return pick((1, "model"), (2, dp))
        if "ssm" in name:
            if name.endswith(("in_z", "in_x")):         # (L, D, di)
                return pick((2, "model"), (1, dp)) if ssm_ok \
                    else pick((1, dp))
            if name.endswith("out_proj"):               # (L, di, D)
                return pick((1, "model"), (2, dp)) if ssm_ok \
                    else pick((2, dp))
            if name.endswith(("in_B", "in_C", "in_dt")):  # (L, D, small)
                return pick((1, dp))
            if name.endswith(("conv_x_w",)):            # (L, dc, di)
                return pick((2, "model")) if ssm_ok else P()
            if name.endswith(("conv_x_b", "norm_scale")):  # (L, di)
                return pick((1, "model")) if ssm_ok else P()
            return P()                                   # a_log, conv_bc, ...
        return P()                                       # norms etc.

    return jax.tree_util.tree_map_with_path(rule, param_shapes)


def moment_specs(param_specs_tree, opt_shapes, mesh: Mesh, fsdp: bool = True):
    """Specs for optimizer state: f32 moments mirror the params; int8
    block-quantized moments shard their flat block dim over the DP axes."""
    dp = dp_axes(mesh) if fsdp else ()

    def rule(path, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        if names and names[0] == "step":
            return P()
        if names[-1] in ("q", "scale"):                 # int8 moment leaves
            nblocks = leaf.shape[0]
            ax = dp if dp and nblocks % int(np.prod(
                [mesh.shape[a] for a in dp])) == 0 else None
            return P(ax, *([None] * (len(leaf.shape) - 1)))
        # f32 moments: same spec as the parameter at the same subpath
        sub = param_specs_tree
        for k in names[1:]:                              # skip 'm'/'v'
            sub = sub[k]
        return sub

    return jax.tree_util.tree_map_with_path(rule, opt_shapes)


def batch_specs(batch_shapes, arch: ArchConfig, shape: InputShape,
                mesh: Mesh, seq_shard: bool = False):
    """Input batch: shard batch over DP axes (guarded), optionally the
    sequence over 'model' (sequence parallelism for long prefill)."""
    dp = dp_axes(mesh)

    def rule(path, leaf):
        dims = dict(enumerate(leaf.shape))
        nd = len(leaf.shape)
        cands = [(0, dp)]
        if seq_shard and nd >= 2:
            cands.append((1, "model"))
        return _spec(nd, _pick(mesh, dims, *cands))

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_specs(cache_shapes, arch: ArchConfig, mesh: Mesh):
    """Decode cache: batch over DP; KV caches shard the *time* axis over
    'model' (uniform across GQA layouts, and the per-step collective is only
    the flash-decode softmax-stats reduction); SSD state shards heads when
    head-aligned.

    Paged pool-native caches (``pool_k/pool_v/near_k/near_v/pos`` — the
    serving engine's single-source-of-truth pytree, ISSUE 5) shard the KV
    HEAD dim over 'model' instead: the fused walk kernel's grid is
    ``(B, Hkv)``, so each device walks its head slice of every mapped page
    and page tables / walk metadata stay replicated (head-agnostic).
    Guarded by ``kv_heads_shardable`` — GQA/MQA head counts that do not
    divide the model axis replicate (and the read path stays bit-identical
    to single-device by construction)."""
    dp = dp_axes(mesh)
    ssm_ok = ssm_heads_shardable(arch, mesh)
    # the paged walk kernel's grid is (B, Hkv) — per-KV-head, never mixing
    # Q-head groups across devices — so the paged guard is Hkv divisibility
    # alone (kv_shard_count), not the dense-TP attn_heads_shardable guard
    paged_ok = kv_shard_count(mesh, arch.n_kv_heads) > 1
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(
        cache_shapes)[0]]
    paged = any("pool_k" in "/".join(str(getattr(k, "key", k)) for k in p)
                for p in paths)

    def rule(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        dims = dict(enumerate(leaf.shape))
        nd = len(leaf.shape)
        if name.endswith(("pool_k", "pool_v")):
            # (L, P, page, Hkv, hd) — or a layer slice (P, page, Hkv, hd):
            # the head dim is always ndim-2
            return _spec(nd, {nd - 2: "model"} if paged_ok else {})
        if paged and name.endswith(("near_k", "near_v")):
            # global near buffer (L, C*page, Hkv, hd) / (C*page, Hkv, hd):
            # a derived copy of pool bytes — sharded exactly like them
            return _spec(nd, {nd - 2: "model"} if paged_ok else {})
        if name.endswith(("/k", "/v")) or name in ("k", "v"):
            # (L, B, T, Hkv, hd)
            return _spec(nd, _pick(mesh, dims, (1, dp), (2, "model")))
        if "ssm" in name:                   # (L, B, H, P, N)
            cands = [(1, dp)] + ([(2, "model")] if ssm_ok else [])
            return _spec(nd, _pick(mesh, dims, *cands))
        if "conv" in name:                  # conv/0: (L,B,dc-1,di); conv/1: 2N
            cands = [(1, dp)]
            if ssm_ok and name.endswith("0"):   # x-path channels, head-aligned
                cands.append((3, "model"))
            return _spec(nd, _pick(mesh, dims, *cands))
        if name.endswith(("near_k", "near_v", "far_k", "far_v",
                          "win_k", "win_v")):
            if nd == 5:                     # (L, B, Tn, Hkv, hd) decode-step
                return _spec(nd, _pick(mesh, dims, (1, dp), (2, "model")))
            return _spec(nd, _pick(mesh, dims, (0, dp), (1, "model")))
        if name.endswith("near_idx"):       # (L, B, near_pages)
            return _spec(nd, _pick(mesh, dims, (1, dp)))
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
