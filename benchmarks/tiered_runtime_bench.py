"""Benchmarks for the TPU-adapted tiered-memory runtime (beyond-paper).

  * tiered_kv: BBC near-tier hit-mass on Zipfian-attention decode streams,
    modeled HBM-bytes saved by the sparse tiered mode, and migration counts —
    the serving-side analogue of the paper's Fig 8.
  * tiered_embedding: near-tier hit rate and modeled lookup-bytes saved on a
    Zipfian token stream (the OS-exposed mechanism analogue).
  * policy_sweep: all four paper policies (SC / WMC / BBC / STATIC) on the
    KV substrate through the one `repro.tier` engine — near-tier hit mass,
    migration counts and modeled byte-cost saved per policy (the serving
    twin of the simulator's fig8_policy_comparison).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiered_embedding as te
from repro.core import tiered_kv as tkv


def bench_tiered_kv(T=4096, page=128, near_pages=8, steps=64, seed=0):
    """Drive a decode stream whose queries concentrate attention on a hot
    page set (Zipfian, like real long-context serving); report near-tier
    mass coverage + modeled byte savings of the sparse tiered mode."""
    cfg = tkv.TieredKVConfig(page=page, near_pages=near_pages, interval=8,
                             max_promotions=2)
    B, Hkv, hd = 2, 2, 64
    H = Hkv * 2
    ks = jax.random.split(jax.random.key(seed), 3)
    k_cache = jax.random.normal(ks[0], (B, T, Hkv, hd), jnp.float32) * 0.1
    v_cache = jax.random.normal(ks[1], (B, T, Hkv, hd), jnp.float32) * 0.1
    # hot pages: boost key alignment with a fixed query direction
    n_pages = T // page
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_pages + 1)
    popularity = ranks ** -1.5
    popularity /= popularity.sum()
    hot = rng.choice(n_pages, size=4, replace=False, p=popularity)
    direction = jax.random.normal(ks[2], (Hkv, hd), jnp.float32)
    k_np = np.array(k_cache)          # writable copy
    for p in hot:
        k_np[:, p * page:(p + 1) * page] += 0.8 * np.asarray(direction)
    cache = tkv.init_tiered_cache(jnp.asarray(k_np), v_cache, cfg)

    pos = jnp.asarray(T - 1, jnp.int32)
    mass_in_near = []
    for step in range(steps):
        q = (jnp.tile(direction.reshape(1, Hkv, 1, hd), (B, 1, 2, 1))
             .reshape(B, H, hd)
             + 0.15 * jax.random.normal(jax.random.key(100 + step),
                                        (B, H, hd)))
        if step % cfg.interval == 0:
            cache = tkv.plan_and_migrate(cache, q, pos, cfg)
        masses = tkv.page_masses(q, cache, pos, cfg)       # (B, n_pages)
        promoted = cache["slot_of_page"] >= 0
        mass_in_near.append(float((masses * promoted).sum() / masses.sum()))

    kv_bytes_full = 2 * T * Hkv * hd * 2                    # per seq, bf16
    near_tokens = near_pages * page
    kv_bytes_sparse = 2 * near_tokens * Hkv * hd * 2
    rows = [
        ("tiered_kv", "near_mass_coverage", round(float(np.mean(
            mass_in_near[-16:])), 3)),
        ("tiered_kv", "migrations", int(cache["migrations"])),
        ("tiered_kv", "bytes_full_per_step", kv_bytes_full),
        ("tiered_kv", "bytes_sparse_mode", kv_bytes_sparse),
        ("tiered_kv", "sparse_bytes_saved_pct",
         round(100 * (1 - kv_bytes_sparse / kv_bytes_full), 1)),
    ]
    return rows


def bench_tiered_embedding(V=32000, D=1024, near_rows=1024, steps=30,
                           batch_tokens=4096, alpha=1.1, seed=0):
    cfg = te.TieredEmbeddingConfig(near_rows=near_rows, max_promotions=128)
    table = jax.random.normal(jax.random.key(seed), (V, D), jnp.float32)
    state = te.init_state(table, cfg)
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, V + 1)
    p = ranks ** -alpha
    p /= p.sum()
    hit = 0.0
    for _ in range(steps):
        toks = jnp.asarray(rng.choice(V, size=batch_tokens, p=p), jnp.int32)
        state = te.record_and_migrate(table, state, toks, cfg)
    toks = jnp.asarray(rng.choice(V, size=batch_tokens, p=p), jnp.int32)
    _, hits = te.lookup(table, state, toks)
    hit = float(hits.mean())
    # modeled bytes: near rows stream from VMEM-resident table (free at HBM),
    # misses gather from HBM at gather-derated bandwidth.
    bytes_all_hbm = batch_tokens * D * 4
    bytes_tiered = int((1 - hit) * batch_tokens * D * 4)
    return [
        ("tiered_embed", "near_hit_rate", round(hit, 3)),
        ("tiered_embed", "migrations", int(state["migrations"])),
        ("tiered_embed", "hbm_bytes_baseline", bytes_all_hbm),
        ("tiered_embed", "hbm_bytes_tiered", bytes_tiered),
        ("tiered_embed", "bytes_saved_pct",
         round(100 * (1 - bytes_tiered / bytes_all_hbm), 1)),
    ]


def _hot_page_cache(T, page, near_pages, policy, seed=0, B=2, Hkv=2, hd=64):
    """A KV cache whose keys concentrate attention on a Zipfian hot-page set,
    plus the query direction that excites it."""
    cfg = tkv.TieredKVConfig(page=page, near_pages=near_pages, interval=8,
                             max_promotions=2, policy=policy)
    ks = jax.random.split(jax.random.key(seed), 3)
    k_cache = jax.random.normal(ks[0], (B, T, Hkv, hd), jnp.float32) * 0.1
    v_cache = jax.random.normal(ks[1], (B, T, Hkv, hd), jnp.float32) * 0.1
    n_pages = T // page
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_pages + 1)
    popularity = ranks ** -1.5
    popularity /= popularity.sum()
    hot = rng.choice(n_pages, size=4, replace=False, p=popularity)
    direction = jax.random.normal(ks[2], (Hkv, hd), jnp.float32)
    k_np = np.array(k_cache)
    for p in hot:
        k_np[:, p * page:(p + 1) * page] += 0.8 * np.asarray(direction)
    cache = tkv.init_tiered_cache(jnp.asarray(k_np), v_cache, cfg)
    return cache, cfg, direction


def _query(direction, step, B, Hkv, hd):
    H = Hkv * 2
    return (jnp.tile(direction.reshape(1, Hkv, 1, hd), (B, 1, 2, 1))
            .reshape(B, H, hd)
            + 0.15 * jax.random.normal(jax.random.key(100 + step),
                                       (B, H, hd)))


def bench_policy_sweep(T=2048, page=128, near_pages=8, steps=48, seed=0):
    """All four policies through the unified engine on the same
    Zipfian-attention decode stream.  Reports, per policy:

      hit_mass          : mean attention mass served by the near tier
                          (paper near-segment hit-rate analogue).
      migrations        : total page copies (IST count; SC thrash shows up
                          here exactly as it does in the DRAM simulator).
      bytes_saved_pct   : modeled HBM byte-cost saved by the exact two-tier
                          read path vs an all-far baseline, with migration
                          traffic amortized in (TierCosts ratios: far pages
                          gather-derated, near pages streamed).
    """
    B, Hkv, hd = 2, 2, 64
    rows = []
    for policy in ("SC", "WMC", "BBC", "STATIC"):
        cache, cfg, direction = _hot_page_cache(T, page, near_pages, policy,
                                                seed, B, Hkv, hd)
        pos = jnp.asarray(T - 1, jnp.int32)
        if policy == "STATIC":
            # profile pass (the paper's OS profiling step), then pin at t=0
            profile = tkv.page_masses(_query(direction, 0, B, Hkv, hd),
                                      cache, pos, cfg)
            cache = tkv.preload_static_kv(cache, profile, pos, cfg)
        mass_in_near = []
        for step in range(steps):
            q = _query(direction, step, B, Hkv, hd)
            if step % cfg.interval == 0:
                cache = tkv.plan_and_migrate(cache, q, pos, cfg)
            masses = tkv.page_masses(q, cache, pos, cfg)
            promoted = cache["slot_of_page"] >= 0
            mass_in_near.append(float((masses * promoted).sum()
                                      / masses.sum()))
        hit_mass = float(np.mean(mass_in_near[-16:]))
        migrations = int(cache["migrations"])
        near_tokens = int((np.asarray(cache["slot_of_page"]) >= 0).sum()
                          / B) * page
        c = cfg.costs
        cost_base = T * c.far_cost
        cost_tiered = ((T - near_tokens) * c.far_cost
                       + near_tokens * c.near_cost
                       + migrations * page * c.migrate_cost / (B * steps))
        saved_pct = 100 * (1 - cost_tiered / cost_base)
        rows.append(("policy_sweep", policy, round(hit_mass, 3), migrations,
                     round(saved_pct, 1)))
    return rows


def run_all():
    rows = (bench_tiered_kv() + bench_tiered_embedding()
            + bench_policy_sweep())
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
