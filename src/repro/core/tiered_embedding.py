"""Tiered embedding table: the paper's OS-exposed mechanism, for LM vocabs.

Token frequencies are Zipfian, exactly the row-popularity skew TL-DRAM
exploits: a small near tier of hot vocabulary rows serves most lookups via
the VMEM-resident fast path (`kernels.tiered_gather`), while the bulk table
stays in HBM (far tier).  The shared vectorized engine
(`repro.tier.jax_engine`) decides membership from decayed token activation
counts under any of the four paper policies (BBC by default; STATIC preloads
from a profiled count vector); `refresh` re-copies hot rows after parameter
updates (training) — the IST analogue.

Applicability: enabled for vocab >= 32k archs; for tiny vocabularies
(musicgen's 2048 codes) the whole table fits the near tier and the mechanism
degenerates (docs/design.md §Arch-applicability).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.tier import TierCosts, ema_update
from repro.tier.jax_engine import (apply_promotions, plan_promotions,
                                   preload_static)
from repro.kernels.tiered_gather import tiered_gather

DEFAULT_COSTS = TierCosts(near_cost=1.0, far_cost=4.0, migrate_cost=6.0,
                          hysteresis=1.5, min_score=2.0, decay=0.9)


@dataclass
class TieredEmbeddingConfig:
    near_rows: int = 1024
    max_promotions: int = 64
    policy: str = "BBC"           # SC | WMC | BBC | STATIC
    costs: TierCosts = DEFAULT_COSTS


def init_state(table: jax.Array, cfg: TieredEmbeddingConfig) -> dict:
    V, D = table.shape
    C = cfg.near_rows
    return {
        "near_table": jnp.zeros((C, D), table.dtype),
        "slot_of_token": -jnp.ones((V,), jnp.int32),
        "token_of_slot": -jnp.ones((C,), jnp.int32),
        "scores": jnp.zeros((V,), jnp.float32),
        # SC/WMC LRU stamps: batch index of each token's last occurrence.
        "last_use": jnp.zeros((V,), jnp.float32),
        "step": jnp.zeros((), jnp.int32),
        "migrations": jnp.zeros((), jnp.int32),
    }


def lookup(table: jax.Array, state: dict, tokens: jax.Array,
           interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Two-tier lookup.  tokens: (...,) int32.  Returns (embeddings, hit_mask).

    Near hits resolve from the VMEM-pinned near table inside the Pallas
    kernel; misses take the HBM gather (far path).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    shape = tokens.shape
    flat = tokens.reshape(-1)
    slots = state["slot_of_token"][flat]
    far_values = jnp.take(table, flat, axis=0)
    out = tiered_gather(state["near_table"], slots, far_values,
                        interpret=interpret)
    hits = slots >= 0
    return out.reshape(*shape, table.shape[1]), hits.reshape(shape)


def record_and_migrate(table: jax.Array, state: dict, tokens: jax.Array,
                       cfg: TieredEmbeddingConfig, idle=True) -> dict:
    """EMA-update token scores with this batch's counts, then run
    ``cfg.policy`` and copy newly-promoted rows into the near tier (pure
    on-device copies).  ``idle`` is the WMC gate (SC/BBC ignore it)."""
    if cfg.policy.upper() == "STATIC":
        return state   # OS-exposed mechanism: no runtime migration, and no
                       # point paying the counting pass for dead state
    state = dict(state)
    V = table.shape[0]
    counts = jnp.zeros((V,), jnp.float32).at[tokens.reshape(-1)].add(1.0)
    state["scores"] = ema_update(state["scores"], counts, cfg.costs)
    state["last_use"] = jnp.where(counts > 0,
                                  state["step"].astype(jnp.float32),
                                  state["last_use"])
    state["step"] = state["step"] + 1

    # SC/WMC cache what was *accessed this batch*; BBC keeps its sustained-
    # reuse eligibility over the full EMA score population.
    accessed = (counts > 0) if cfg.policy.upper() in ("SC", "WMC") else None
    rows, slots, valid = plan_promotions(
        state["scores"], state["slot_of_token"], state["token_of_slot"],
        cfg.costs, cfg.max_promotions, policy=cfg.policy,
        last_use=state["last_use"], accessed=accessed, idle=idle)
    state["slot_of_token"], state["token_of_slot"] = apply_promotions(
        state["slot_of_token"], state["token_of_slot"], rows, slots, valid)

    # IST: copy promoted rows (scatter into the near table, no collectives)
    safe_rows = jnp.where(valid, rows, 0)
    new_rows = jnp.take(table, safe_rows, axis=0)
    dst = jnp.where(valid, slots, state["near_table"].shape[0])
    state["near_table"] = state["near_table"].at[dst].set(new_rows,
                                                          mode="drop")
    state["migrations"] = state["migrations"] + valid.sum().astype(jnp.int32)
    return state


def preload_static_embedding(table: jax.Array, state: dict,
                             profile_counts: jax.Array,
                             cfg: TieredEmbeddingConfig) -> dict:
    """OS-exposed static placement: pin the profile's hottest tokens in the
    near tier at t=0 (serve with ``policy="STATIC"``, no runtime migration).

    profile_counts: (V,) profiled token frequencies."""
    state = dict(state)
    C = state["token_of_slot"].shape[0]
    state["slot_of_token"], state["token_of_slot"] = preload_static(
        profile_counts.astype(jnp.float32), C)
    return refresh(table, state)


def refresh(table: jax.Array, state: dict) -> dict:
    """Re-copy every cached row from the (possibly updated) master table —
    call after optimizer steps touching the embedding."""
    state = dict(state)
    C = state["token_of_slot"].shape[0]
    toks = state["token_of_slot"]
    rows = jnp.take(table, jnp.maximum(toks, 0), axis=0)
    live = (toks >= 0)[:, None]
    state["near_table"] = jnp.where(live, rows.astype(state["near_table"].dtype),
                                    state["near_table"])
    return state


def hit_rate(state: dict, tokens: jax.Array) -> jax.Array:
    return (state["slot_of_token"][tokens.reshape(-1)] >= 0).mean()
