"""GPipe-style pipeline parallelism over the 'pod' mesh axis.

The default multi-pod layout treats 'pod' as outer data parallelism; this
module provides the alternative: layers split into one stage per pod,
microbatches streamed through stages with ``shard_map`` + ``ppermute``
(jax-native collective-permute — the cross-pod DCN/ICI hop), compute
overlapping communication in the classic fill/steady/drain schedule.

Design notes:
  * stage function must be shape-preserving on (B_mb, S, D) activations —
    true for every decoder block here;
  * stage parameters are stacked on a leading stage axis sharded over 'pod'
    (each pod holds only its stage's layers);
  * the schedule runs M + P - 1 ticks for M microbatches and P stages; the
    bubble fraction (P-1)/(M+P-1) is reported by ``bubble_fraction``;
  * within a stage, all other axes ('data', 'model') keep their usual roles,
    so PP composes with DP/TP/FSDP.

The multi-pod dry-run lowers a pipelined train step for qwen3
(`launch/dryrun.py --pipeline`), proving the pod axis shards under this
schedule too; numerics are tested on a 1-stage mesh (identity schedule) in
tests and exactness across stages is asserted by construction (each tick
applies the same block function).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def gpipe_apply(block_fn, layer_params, x, mesh: Mesh,
                n_microbatches: int, axis: str = "pod"):
    """Run ``block_fn(local_layer_params, x_mb) -> x_mb`` through P stages.

    layer_params: pytree with leading layer dim L, *already sharded over
    ``axis`` on that dim at the jit boundary* (see ``pp_param_specs``) —
    shard_map then hands each pod its own L/P layer slice with no resharding.
    x: (B, S, D) activations (B divisible by n_microbatches).
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_microbatches == 0
    mb = B // n_microbatches

    # Fully-manual shard_map: stages over `axis`, batch over the data axes,
    # weights replicated across 'model' inside the stage.  (Mixed
    # manual/auto shard_map — which would let GSPMD run TP inside each
    # stage — trips an XLA CPU SPMD-partitioner check-failure on this
    # container [b/433785288]; on TPU backends / Shardy the mixed mode is
    # the intended composition.  Embedding and LM head remain vocab-sharded
    # outside the pipelined region either way.)
    pspec = jax.tree.map(lambda _: P(axis), layer_params)
    data_axes = tuple(a for a in mesh.axis_names if a not in (axis, "model"))
    xspec = P(data_axes if data_axes else None)   # batch over data, repl. over pod
    manual = set(mesh.axis_names)

    def _shard_map(f):
        # jax >= 0.6 exposes jax.shard_map(axis_names=..., check_vma=...);
        # on 0.4.x the same fully-manual mode is the experimental API's
        # default (auto=frozenset()) with check_rep as the toggle.
        if hasattr(jax, "shard_map"):
            return jax.shard_map(f, mesh=mesh, axis_names=manual,
                                 in_specs=(pspec, xspec), out_specs=xspec,
                                 check_vma=False)
        from jax.experimental.shard_map import shard_map
        return shard_map(f, mesh=mesh, in_specs=(pspec, xspec),
                         out_specs=xspec, check_rep=False)

    @_shard_map
    def run(params_local, x_local):
        stage = jax.lax.axis_index(axis)
        n_ticks = n_microbatches + n_stages - 1
        mb_local = x_local.shape[0] // n_microbatches
        assert mb_local >= 1, (x_local.shape, n_microbatches)
        x_mb = x_local.reshape(n_microbatches, mb_local, *x_local.shape[1:])
        buf = jnp.zeros_like(x_mb[0])
        out = jnp.zeros_like(x_mb)

        def tick(t, carry):
            buf, out = carry
            # stage 0 ingests microbatch t (when in range)
            feed = x_mb[jnp.clip(t, 0, n_microbatches - 1)]
            inp = jnp.where(stage == 0, feed, buf)
            active = (t >= stage) & (t - stage < n_microbatches)
            y = block_fn(params_local, inp)
            y = jnp.where(active, y, buf)
            # last stage emits microbatch (t - stage)
            idx = jnp.clip(t - stage, 0, n_microbatches - 1)
            emit = active & (stage == n_stages - 1)
            out = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, idx, 0),
                lambda o: o, out)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, out

        _, out = jax.lax.fori_loop(0, n_ticks, tick, (buf, out))
        # Only the last stage holds real outputs; broadcast to every stage
        # (masked psum) so the result is replicated over `axis`.
        if n_stages > 1:
            out = jax.lax.psum(
                jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)),
                axis)
        return out.reshape(x_local.shape)

    return run(layer_params, x)


def pp_param_specs(param_shapes, arch, mesh: Mesh, axis: str = "pod",
                   fsdp: bool = True):
    """Standard param specs, with every layer-stacked leaf's leading layer
    dim additionally sharded over the pipeline axis (the stage split)."""
    from repro.sharding import specs as sh

    # 'pod' is the pipeline axis; layer weights enter the fully-manual
    # pipelined region replicated over 'model'/'data' (see gpipe_apply).
    base = sh.param_specs(param_shapes, arch, mesh, fsdp=False)

    def add_stage_dim(path, spec, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        if names and names[0] == "layers":
            return P(axis, *([None] * (len(leaf.shape) - 1)))
        return spec

    return jax.tree_util.tree_map_with_path(add_stage_dim, base, param_shapes)


def split_stages(stacked_layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (P, L/P, ...) stage-stacked."""
    def f(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape(n_stages, L // n_stages, *a.shape[1:])
    return jax.tree.map(f, stacked_layer_params)


def make_pp_loss_fn(arch, mesh: Mesh, n_microbatches: int = 8,
                    axis: str = "pod"):
    """A pipelined forward+loss for decoder archs: layers split into one
    stage per pod, each stage scanning its layer slice.  Composes with the
    usual DP/TP shardings on the other axes.  Used by the dry-run to prove
    the pod axis pipelines (`--pipeline`)."""
    from repro.models import transformer  # local import avoids cycles

    n_stages = mesh.shape[axis]
    assert arch.n_layers % n_stages == 0, (arch.n_layers, n_stages)

    def block_fn(stage_params, x):
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                     (x.shape[0], S))

        def body(h, layer_params):
            h, *_ = transformer._block_train(layer_params, h, positions,
                                             arch)
            return h, None

        x, _ = jax.lax.scan(body, x, stage_params)
        return x

    def loss_fn(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0
                     ).astype(jnp.bfloat16)
        cparams = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if a.dtype == jnp.float32 and a.ndim > 1 else a,
            params["layers"])
        x = gpipe_apply(block_fn, cparams, x, mesh, n_microbatches, axis)
        from repro.models.layers import rms_norm
        x = rms_norm(x, params["final_norm"].astype(jnp.bfloat16))
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params.get("lm_head", params["embed"].T))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        return nll.mean()

    return loss_fn
