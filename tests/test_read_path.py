"""Correctness sweep of the near-tier read path (ISSUE 2 satellites).

Three classes of bug this file pins down:

  * TestNearTierOccupancyMask — the sparse tiered decode step must mask
    near-tier slots by *occupancy*: an empty (all-zero / stale) near slot
    contributes score-0 logits to the softmax if attended, corrupting the
    output whenever the near tier is not yet full.
  * TestNearKernelBlockGeometry — the Pallas near-tier kernel must pad the
    buffer to the block multiple instead of shrinking ``block_kv`` by
    halving (which degenerates to block size 1-2 for awkward ``T_near``).
  * TestOccupiedSlotsPrefixInvariant — ``core.tiered_kv.tiered_attention``
    reads ``occupied.sum() * page`` near tokens, which is only sound if the
    occupied slots always form a prefix; replayed SC/WMC/BBC
    promotion/eviction streams pin that invariant on the shared engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core import tiered_kv as tkv
from repro.kernels import ref
from repro.kernels.tiered_attention import (_block_geometry,
                                            near_decode_attention)
from repro.launch import serve
from repro.models import transformer
from repro.tier import TierCosts, jax_engine


# ---------------------------------------------------------------------------
# Satellite: empty near slots must be masked out of the sparse decode step
# ---------------------------------------------------------------------------

class TestNearTierOccupancyMask:
    def _setup(self, near_fill=0.0):
        """A mid-stream sparse-decode state whose near tier is half full.

        Geometry: page=16, near_pages=2 (one occupied), window=32,
        pos=47.  After the step writes the current token, the window ring
        holds positions 16..47 and the occupied near page holds 0..15, so
        (near U window) covers the full history and the sparse step must
        equal the standard full-cache decode step exactly.  The *empty*
        near page is filled with ``near_fill`` — any leak into the softmax
        is the bug.
        """
        arch = ARCHS["yi-9b"].reduced()
        page, near_pages, window = 16, 2, 32
        S = page + window - 1                     # 47: current token is pos 47
        B, max_len = 2, 64
        params = transformer.init_params(jax.random.key(0), arch)
        tokens = jax.random.randint(jax.random.key(1), (B, S), 0, arch.vocab)
        _, cache = transformer.prefill(params, {"tokens": tokens}, arch,
                                       max_len=max_len)
        k, v = np.asarray(cache["k"]), np.asarray(cache["v"])
        L, _, _, Hkv, hd = k.shape
        Tn = near_pages * page

        near_k = np.full((L, B, Tn, Hkv, hd), near_fill, k.dtype)
        near_v = np.full((L, B, Tn, Hkv, hd), near_fill, v.dtype)
        near_k[:, :, :page] = k[:, :, :page]      # page 0 promoted
        near_v[:, :, :page] = v[:, :, :page]
        win_k = np.zeros((L, B, window, Hkv, hd), k.dtype)
        win_v = np.zeros((L, B, window, Hkv, hd), v.dtype)
        for p in range(S - window, S):            # ring: positions 15..46
            win_k[:, :, p % window] = k[:, :, p]
            win_v[:, :, p % window] = v[:, :, p]

        sparse_cache = {
            "k": cache["k"], "v": cache["v"], "pos": cache["pos"],
            "near_k": jnp.asarray(near_k), "near_v": jnp.asarray(near_v),
            "win_k": jnp.asarray(win_k), "win_v": jnp.asarray(win_v),
            "near_len": jnp.full((L, B), page, jnp.int32),
        }
        tok = jnp.full((B, 1), 7, jnp.int32)
        return arch, params, cache, sparse_cache, tok, page, near_pages, window

    def test_half_full_near_tier_is_exact(self):
        """Sparse step == standard decode step when (near U window) covers
        the whole history — with the near tier only half full."""
        (arch, params, cache, sparse_cache, tok,
         page, near_pages, window) = self._setup(near_fill=0.0)
        step = serve.make_sparse_tiered_decode_step(
            arch, near_pages=near_pages, page=page, window=window)
        got, _ = step(params, sparse_cache, {"tokens": tok})
        want, _ = transformer.decode_step(params, cache, {"tokens": tok},
                                          arch)
        # bf16 caches: the two exact-math paths (direct softmax vs two-pass
        # LSE merge) differ by bf16 accumulation noise ~3e-2; the unmasked
        # bug produced errors ~4.9 on 100% of elements (100x separation).
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=4e-2, atol=4e-2)

    def test_empty_slot_contents_cannot_leak(self):
        """Whatever garbage sits in unoccupied near slots must not change
        the output (stale evicted pages, huge values, anything)."""
        arch, params, _, sc_a, tok, page, near_pages, window = self._setup(0.0)
        sc_b = self._setup(near_fill=5.0)[3]
        step = serve.make_sparse_tiered_decode_step(
            arch, near_pages=near_pages, page=page, window=window)
        out_a, _ = step(params, sc_a, {"tokens": tok})
        out_b, _ = step(params, sc_b, {"tokens": tok})
        np.testing.assert_allclose(np.asarray(out_a, np.float32),
                                   np.asarray(out_b, np.float32),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: kernel block geometry — pad, never shrink to tiny blocks
# ---------------------------------------------------------------------------

class TestNearKernelBlockGeometry:
    def test_geometry_pads_instead_of_shrinking(self):
        # T=130 used to degenerate to block_kv=2 via repeated halving.
        assert _block_geometry(130, 128) == (128, 256)
        assert _block_geometry(99, 128) == (99, 99)     # single block
        assert _block_geometry(256, 128) == (128, 256)  # exact multiple
        assert _block_geometry(257, 128) == (128, 384)
        block, padded = _block_geometry(5 * 33, 128)
        assert block >= 128 or padded == block          # never tiny blocks
        assert padded % block == 0

    @pytest.mark.parametrize("T", [130, 165, 257])
    def test_awkward_near_lengths_stay_exact(self, T):
        B, H, Hkv, hd = 2, 4, 2, 32
        ks = jax.random.split(jax.random.key(5), 4)
        q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, T, Hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, T, Hkv, hd), jnp.float32)
        length = jax.random.randint(ks[3], (B,), 1, T + 1)
        out, m, l = near_decode_attention(q, k, v, length, block_kv=128,
                                          interpret=True)
        want_out, want_m, want_l = ref.decode_attention_stats_ref(
            q[:, None], k, v, length)
        np.testing.assert_allclose(np.asarray(m), np.asarray(want_m),
                                   rtol=1e-5, atol=1e-5)
        got = np.asarray(out) / np.maximum(np.asarray(l)[..., None], 1e-30)
        want = (np.asarray(want_out)
                / np.maximum(np.asarray(want_l)[..., None], 1e-30))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: occupied near slots always form a prefix (SC/WMC eviction paths)
# ---------------------------------------------------------------------------

def _assert_mapping_invariants(slot_of, row_of):
    so, ro = np.asarray(slot_of), np.asarray(row_of)
    occ = ro >= 0
    n_occ = int(occ.sum())
    assert occ[:n_occ].all(), f"occupied slots not a prefix: {ro}"
    live_rows = ro[occ]
    assert len(set(live_rows.tolist())) == n_occ, f"duplicate rows: {ro}"
    for slot, row in enumerate(ro):
        if row >= 0:
            assert so[row] == slot, (so, ro)
    for row in range(so.shape[0]):
        if so[row] >= 0:
            assert ro[so[row]] == row, (so, ro)


class TestOccupiedSlotsPrefixInvariant:
    @pytest.mark.parametrize("policy", ["SC", "WMC", "BBC"])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_engine_replay_keeps_prefix(self, policy, seed):
        """Replay promotion/eviction streams through the shared engine and
        assert after every interval that occupied slots form a prefix —
        the property ``tiered_attention``'s ``count * page`` read depends on.
        """
        N, C = 24, 5
        costs = TierCosts(near_cost=1.0, far_cost=4.0, migrate_cost=2.0,
                          hysteresis=0.5, min_score=0.5, decay=0.8)
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, N + 1)
        p = ranks ** -1.2
        p /= p.sum()
        scores = jnp.zeros((N,), jnp.float32)
        last_use = jnp.zeros((N,), jnp.float32)
        slot_of = -jnp.ones((N,), jnp.int32)
        row_of = -jnp.ones((C,), jnp.int32)
        for step in range(50):
            batch = rng.choice(N, size=8, p=p)
            counts = np.bincount(batch, minlength=N).astype(np.float32)
            scores = jax_engine.ema_update(scores, jnp.asarray(counts), costs)
            last_use = jnp.where(jnp.asarray(counts) > 0, float(step),
                                 last_use)
            idle = bool(rng.integers(0, 2)) if policy == "WMC" else True
            rows, slots, valid = jax_engine.plan_promotions(
                scores, slot_of, row_of, costs,
                max_promotions=int(rng.integers(1, 4)), policy=policy,
                last_use=last_use, accessed=jnp.asarray(counts) > 0,
                idle=idle)
            slot_of, row_of = jax_engine.apply_promotions(
                slot_of, row_of, rows, slots, valid)
            _assert_mapping_invariants(slot_of, row_of)

    @pytest.mark.parametrize("policy", ["SC", "WMC"])
    def test_kv_substrate_replay_keeps_prefix(self, policy):
        """Same invariant end-to-end through plan_and_migrate on the KV
        substrate, with per-sequence (ragged) positions."""
        cfg = tkv.TieredKVConfig(page=32, near_pages=3, interval=4,
                                 max_promotions=2, policy=policy)
        B, T, Hkv, hd = 2, 256, 2, 16
        ks = jax.random.split(jax.random.key(11), 2)
        k = jax.random.normal(ks[0], (B, T, Hkv, hd), jnp.float32)
        v = jax.random.normal(ks[1], (B, T, Hkv, hd), jnp.float32)
        cache = tkv.init_tiered_cache(k, v, cfg)
        pos = jnp.asarray([T // 2 + 3, T - 5], jnp.int32)
        for step in range(8):
            q = jax.random.normal(jax.random.key(100 + step),
                                  (B, Hkv * 2, hd))
            cache = tkv.plan_and_migrate(cache, q, pos, cfg,
                                         idle=(step % 2 == 0))
            for b in range(B):
                _assert_mapping_invariants(cache["slot_of_page"][b],
                                           cache["page_of_slot"][b])
            occupied = (np.asarray(cache["page_of_slot"]) >= 0).sum(1)
            near_len = occupied * cfg.page
            assert (near_len <= cfg.near_pages * cfg.page).all()
