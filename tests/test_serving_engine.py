"""Continuous-batching serving engine tests (ISSUE 2 tentpole).

The load-bearing property: one batched ragged-``pos`` decode step over the
slot pool emits, for every in-flight sequence, exactly the token the
single-sequence ``greedy_generate`` reference would emit — continuous
batching changes the schedule, never the tokens.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core import tiered_kv as tkv
from repro.core.tiered_kv import TieredKVConfig
from repro.kernels import ref
from repro.launch.serve import greedy_generate
from repro.models import transformer
from repro.serve import ServingConfig, ServingEngine, sequential_baseline
from repro.serve.trace import Request, SCENARIOS


def _arch_params(seed=0):
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(seed), arch)
    return arch, params


def _staggered_trace(vocab, rng):
    """5 requests, 2 prompt-length buckets, staggered arrivals."""
    lens = [20, 12, 20, 12, 20]
    arrivals = [0, 1, 3, 6, 10]
    return [Request(rid=i, arrival=arrivals[i],
                    prompt=rng.integers(0, vocab, lens[i]).astype(np.int32),
                    max_new_tokens=8)
            for i in range(5)]


class TestServingEngineE2E:
    def test_staggered_arrivals_match_greedy_reference_and_reuse_slots(self):
        arch, params = _arch_params()
        rng = np.random.default_rng(7)
        trace = _staggered_trace(arch.vocab, rng)
        tier = TieredKVConfig(page=16, near_pages=2, interval=3,
                              policy="BBC")
        cfg = ServingConfig(n_slots=3, max_len=64, prefill_bucket=16,
                            tier=tier, verify_tiered_read=True)
        eng = ServingEngine(params, arch, cfg)
        rep = eng.run(trace, "staggered")

        # every request ran to completion
        assert sorted(rep.outputs) == [0, 1, 2, 3, 4]
        assert all(len(v) == 8 for v in rep.outputs.values())
        # every emitted token matches the single-sequence reference
        for req in trace:
            want, _ = greedy_generate(
                params, arch, {"tokens": req.prompt[None]}, steps=8,
                max_len=cfg.max_len)
            assert rep.outputs[req.rid] == np.asarray(want)[0].tolist(), \
                f"rid {req.rid} diverges from greedy_generate"
        # 5 requests through 3 slots => at least one slot served twice
        assert any(len(rids) >= 2 for rids in rep.slot_history.values()), \
            rep.slot_history
        assert sum(len(r) for r in rep.slot_history.values()) == 5
        # the tiered read-path probe stayed at bf16 noise level
        assert rep.max_read_err < 5e-2

    @pytest.mark.parametrize("policy", ["SC", "STATIC"])
    def test_other_policies_keep_decode_exact(self, policy):
        """The tier policy only moves copies; emitted tokens never change."""
        arch, params = _arch_params(seed=1)
        rng = np.random.default_rng(11)
        trace = _staggered_trace(arch.vocab, rng)
        tier = TieredKVConfig(page=16, near_pages=2, interval=3,
                              policy=policy)
        cfg = ServingConfig(n_slots=3, max_len=64, prefill_bucket=16,
                            tier=tier)
        rep = ServingEngine(params, arch, cfg).run(trace, "staggered")
        base = sequential_baseline(params, arch, trace, cfg)
        assert rep.outputs == base.outputs


class TestSequentialBaselineTTFT:
    def test_ttft_is_modeled_prefill_cost_not_zero(self):
        """Bugfix (ISSUE 4 satellite, red test first): the baseline recorded
        ``ttfts.append(0.0)`` while the engine's TTFT includes the modeled
        prefill cost, so engine-vs-sequential TTFT columns compared different
        timebases.  The baseline's first token costs exactly its prompt's
        modeled prefill under the same CostModel."""
        arch, params = _arch_params(seed=4)
        rng = np.random.default_rng(5)
        trace = _staggered_trace(arch.vocab, rng)
        tier = TieredKVConfig(page=16, near_pages=2, interval=3)
        cfg = ServingConfig(n_slots=3, max_len=64, prefill_bucket=16,
                            tier=tier)
        rep = sequential_baseline(params, arch, trace, cfg)
        want = [cfg.cost.prefill_cost(len(r.prompt))
                for r in sorted(trace, key=lambda r: (r.arrival, r.rid))]
        assert rep.ttfts == pytest.approx(want), \
            "sequential TTFT must be the modeled prefill cost, not 0.0"
        assert rep.p50_ttft > 0
        # the first inter-token latency of each request IS its TTFT (the
        # engine records it the same way, so the columns share a timebase)
        n_per = trace[0].max_new_tokens
        firsts = rep.token_latencies[::n_per]
        assert firsts == pytest.approx(want)


class TestRaggedDecodePath:
    def test_vector_pos_equals_scalar_pos(self):
        """decode_step with pos broadcast to a (B,) vector reproduces the
        scalar-pos step exactly (same math, ragged plumbing)."""
        arch, params = _arch_params(seed=2)
        B, S = 3, 24
        toks = jax.random.randint(jax.random.key(3), (B, S), 0, arch.vocab)
        _, cache = transformer.prefill(params, {"tokens": toks}, arch,
                                       max_len=48)
        step_tok = jnp.full((B, 1), 5, jnp.int32)
        la, ca = transformer.decode_step(params, cache, {"tokens": step_tok},
                                         arch)
        cache_v = dict(cache)
        cache_v["pos"] = jnp.full((B,), S, jnp.int32)
        lb, cb = transformer.decode_step(params, cache_v,
                                         {"tokens": step_tok}, arch)
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ca["k"], np.float32),
                                   np.asarray(cb["k"], np.float32))
        assert cb["pos"].shape == (B,) and int(cb["pos"][0]) == S + 1

    def test_ragged_rows_match_their_single_sequence_run(self):
        """Each slot of a ragged batch gets exactly what it would get in a
        batch of one at its own position."""
        arch, params = _arch_params(seed=3)
        lens = [10, 17, 23]
        max_len = 32
        prompts = [jax.random.randint(jax.random.key(40 + i), (1, n), 0,
                                      arch.vocab) for i, n in enumerate(lens)]
        # singles: per-sequence scalar-pos decode
        single_logits = []
        for p_toks in prompts:
            _, c = transformer.prefill(params, {"tokens": p_toks}, arch,
                                       max_len=max_len)
            l, _ = transformer.decode_step(
                params, c, {"tokens": jnp.full((1, 1), 9, jnp.int32)}, arch)
            single_logits.append(np.asarray(l, np.float32)[0])
        # pooled: one ragged batched step
        pool = transformer.init_cache(arch, 3, max_len)
        k = np.asarray(pool["k"]) + 0.0
        v = np.asarray(pool["v"]) + 0.0
        for i, p_toks in enumerate(prompts):
            _, c = transformer.prefill(params, {"tokens": p_toks}, arch,
                                       max_len=max_len)
            k[:, i] = np.asarray(c["k"])[:, 0]
            v[:, i] = np.asarray(c["v"])[:, 0]
        pool["k"], pool["v"] = jnp.asarray(k), jnp.asarray(v)
        pool["pos"] = jnp.asarray(lens, jnp.int32)
        lp, _ = transformer.decode_step(
            params, pool, {"tokens": jnp.full((3, 1), 9, jnp.int32)}, arch)
        for i in range(3):
            np.testing.assert_allclose(np.asarray(lp, np.float32)[i],
                                       single_logits[i], rtol=2e-2,
                                       atol=2e-2)
            assert int(np.argmax(np.asarray(lp, np.float32)[i, 0])) == \
                int(np.argmax(single_logits[i][0]))

    def test_tiered_attention_ragged_pos_exact(self):
        """Two-tier attention with per-sequence positions equals monolithic
        attention with per-sequence lengths, after migrations."""
        cfg = TieredKVConfig(page=32, near_pages=3, interval=4,
                             max_promotions=2, policy="BBC")
        B, T, Hkv, hd = 3, 256, 2, 32
        ks = jax.random.split(jax.random.key(21), 3)
        k = jax.random.normal(ks[0], (B, T, Hkv, hd), jnp.float32) * 0.5
        v = jax.random.normal(ks[1], (B, T, Hkv, hd), jnp.float32) * 0.5
        cache = tkv.init_tiered_cache(k, v, cfg)
        q = jax.random.normal(ks[2], (B, Hkv * 2, hd), jnp.float32)
        pos = jnp.asarray([100, 157, 249], jnp.int32)
        for _ in range(3):
            cache = tkv.plan_and_migrate(cache, q, pos, cfg)
        assert int(cache["migrations"]) > 0
        got = tkv.tiered_attention(cache, q, pos, cfg)
        want = ref.decode_attention_ref(q[:, None], cache["far_k"],
                                        cache["far_v"], pos)[:, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_page_completion_guard_is_per_sequence(self):
        """A page complete for one slot but mid-write for another may only
        be promoted for the former."""
        cfg = TieredKVConfig(page=32, near_pages=2, interval=1,
                             max_promotions=2, policy="SC")
        B, T, Hkv, hd = 2, 128, 2, 16
        k = jnp.ones((B, T, Hkv, hd), jnp.float32)
        cache = tkv.init_tiered_cache(k, k, cfg)
        q = jnp.ones((B, Hkv * 2, hd), jnp.float32)
        pos = jnp.asarray([40, 8], jnp.int32)   # seq0: page0 done; seq1: none
        for _ in range(2):
            cache = tkv.plan_and_migrate(cache, q, pos, cfg)
        assert int((cache["page_of_slot"][0] >= 0).sum()) > 0
        assert int((cache["page_of_slot"][1] >= 0).sum()) == 0


@pytest.mark.slow
class TestServingBenchFull:
    def test_all_scenarios_all_policies_and_speedup(self):
        """Acceptance: 4 scenarios x 4 policies produce reports, continuous
        batching sustains >= 2x sequential greedy_generate on steady Zipfian
        with identical tokens, and prefix sharing saves >= 40% prefill
        tokens on shared-system-prompt (both asserted inside run_all)."""
        from benchmarks import serving_bench
        rows = serving_bench.run_all()
        scenario_rows = [r for r in rows if r[0] in SCENARIOS]
        assert len(scenario_rows) == 24   # 6 scenarios x 4 policies
        prefix_rows = {r[1]: r[2] for r in rows if r[0] == "prefix_sharing"}
        assert prefix_rows["prefill_tokens_saved_frac"] >= 0.4
        assert prefix_rows["outputs_identical"] is True
        assert prefix_rows["chat_prefix_hit_rate"] > 0
        # ISSUE 8 bench-lie re-pins, on the committed matrix itself: the
        # shifting_hotspot rows must not duplicate steady_zipfian's, the
        # shared-prefix scenarios must show non-zero prefix hits, and no
        # cell may report live KV above its dense equivalent.
        header = list(rows[0])
        by_scenario = {}
        for r in scenario_rows:
            by_scenario.setdefault(r[0], []).append(r)
        assert by_scenario["shifting_hotspot"] != \
            [("shifting_hotspot",) + tuple(r[1:])
             for r in by_scenario["steady_zipfian"]]
        hit_col = header.index("prefix_hit_rate")
        ratio_col = header.index("kv_live_ratio")
        for name in ("shared_system_prompt", "shifting_hotspot",
                     "long_context_summarize"):
            assert all(r[hit_col] > 0 for r in by_scenario[name]), \
                f"{name}: prefix_hit_rate must be > 0 with sharing on"
        assert all(r[ratio_col] <= 1.0 for r in scenario_rows)


def test_serving_bench_smoke():
    """Fast single-cell bench smoke (full matrix is @slow)."""
    from benchmarks import serving_bench
    arch, params = serving_bench._setup()
    cfg = serving_bench._config("BBC", n_slots=3, max_len=64)
    trace = SCENARIOS["steady_zipfian"](arch.vocab, n_requests=4,
                                        prompt_len=16, max_new_tokens=6,
                                        gap=2)
    rep = ServingEngine(params, arch, cfg).run(trace, "steady_zipfian")
    assert rep.tokens == 24
    row = rep.summary_row()
    assert len(row) == len(rep.HEADER)
