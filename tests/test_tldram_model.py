"""Circuit-model tests: Table 1 anchors and every Fig. 5/6/7 trend."""

import numpy as np
import pytest

from repro.core import tldram


TOL = 0.02  # 2% on calibrated anchors


class TestTable1:
    def test_trc_anchors(self):
        model = tldram.table1_model(calibrated=True)
        for name, target in tldram.TABLE1_TRC_NS.items():
            assert model[name].t_rc == pytest.approx(target, rel=TOL), name

    def test_trcd_anchors(self):
        model = tldram.table1_model(calibrated=True)
        assert model["long_512"].t_rcd == pytest.approx(13.75, rel=TOL)
        assert model["short_32"].t_rcd == pytest.approx(8.0, rel=TOL)

    def test_far_trcd_reduced_tras_increased(self):
        """Paper Sec. 3: 'tRCD for the far segment is reduced while its tRAS
        is increased' (relative to the unsegmented long bitline)."""
        model = tldram.table1_model(calibrated=True)
        assert model["far_480"].t_rcd < model["long_512"].t_rcd
        assert model["far_480"].t_ras > model["long_512"].t_ras
        assert model["far_480"].t_rp > model["long_512"].t_rp

    def test_near_matches_short(self):
        """The near segment is electrically a short bitline (+ iso junction)."""
        model = tldram.table1_model(calibrated=True)
        assert model["near_32"].t_rc == pytest.approx(model["short_32"].t_rc,
                                                      rel=0.03)


class TestFig5Trends:
    """The three conclusions the paper draws from Figs. 5a/5b."""

    @pytest.fixture(scope="class")
    def sweep(self):
        return tldram.segment_length_sweep(near_lengths=(16, 32, 64, 128, 256))

    def test_shorter_near_is_faster(self, sweep):
        lengths = sorted(sweep["near"])
        trcs = [sweep["near"][n].t_rc for n in lengths]
        trcds = [sweep["near"][n].t_rcd for n in lengths]
        assert trcs == sorted(trcs)
        assert trcds == sorted(trcds)

    def test_longer_far_has_lower_trcd(self, sweep):
        lengths = sorted(sweep["far"])
        trcds = [sweep["far"][n].t_rcd for n in lengths]
        assert trcds == sorted(trcds, reverse=True)

    def test_shorter_far_has_lower_trc(self, sweep):
        lengths = sorted(sweep["far"])
        trcs = [sweep["far"][n].t_rc for n in lengths]
        assert trcs == sorted(trcs)


class TestWaveforms:
    """Fig. 6/7 dynamics."""

    def test_near_tracks_short_bitline(self):
        m = tldram.BitlineModel()
        near = m.activation_waveform(32, 480, access_far=False)
        short = m.activation_waveform(32, None, access_far=False)
        n = min(len(near.v_near), len(short.v_near))
        np.testing.assert_allclose(near.v_near[:n], short.v_near[:n], atol=0.02)

    def test_far_segment_lags_near_node(self):
        """Fig. 6b: through the iso FET, far voltage rises more slowly than
        the sense-amp (near) node once amplification starts."""
        m = tldram.BitlineModel()
        wf = m.activation_waveform(32, 480, access_far=True)
        p = m.p
        sa_on = int(p.t_share_ns / p.dt_ns)
        late = slice(sa_on + 200, sa_on + 2000)
        assert np.all(wf.v_near[late] >= wf.v_far[late] - 1e-9)

    def test_voltages_bounded(self):
        m = tldram.BitlineModel()
        for access_far in (False, True):
            wf = m.activation_waveform(32, 480, access_far=access_far)
            assert np.all(wf.v_near <= m.p.vdd + 1e-6)
            assert np.all(wf.v_near >= 0.5 * m.p.vdd - 1e-6)

    def test_precharge_settles_to_half_vdd(self):
        p = tldram.CircuitParams()
        wf = tldram._euler_precharge(p, c_near=p.c_bl(512), c_far=None,
                                     t_max_ns=400.0)
        assert wf.v_near[-1] == pytest.approx(0.5 * p.vdd, rel=0.01)


class TestMonotonicity:
    def test_unsegmented_latency_increases_with_cells(self):
        prev = 0.0
        for cells in (32, 64, 128, 256, 512):
            t = tldram.calibrated_timings("unsegmented", cells)
            assert t.t_rc > prev
            prev = t.t_rc

    def test_far_slower_than_long_for_same_total(self):
        far = tldram.calibrated_timings("far", 480, 32)
        long_ = tldram.calibrated_timings("unsegmented", 512)
        assert far.t_rc > long_.t_rc
