"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # optional fast path: real hypothesis
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:                    # seeded fallback harness (tests/_prop)
    from _prop import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_gather import paged_gather
from repro.kernels.ssd_scan import ssd_chunk_scan
from repro.kernels.tiered_attention import near_decode_attention
from repro.kernels.tiered_gather import tiered_gather


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,Hkv,hd,bq,bkv", [
        (1, 128, 4, 4, 64, 64, 64),      # MHA
        (2, 256, 8, 2, 64, 128, 128),    # GQA 4:1
        (1, 128, 4, 1, 128, 128, 64),    # MQA, wide head
        (2, 64, 2, 2, 32, 32, 32),       # tiny
    ])
    def test_against_ref(self, dtype, B, S, H, Hkv, hd, bq, bkv):
        ks = jax.random.split(jax.random.key(0), 3)
        q = _rand(ks[0], (B, S, H, hd), dtype)
        k = _rand(ks[1], (B, S, Hkv, hd), dtype)
        v = _rand(ks[2], (B, S, Hkv, hd), dtype)
        got = flash_attention_fwd(q, k, v, causal=True, block_q=bq,
                                  block_kv=bkv, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL[dtype])

    def test_sliding_window(self):
        ks = jax.random.split(jax.random.key(1), 3)
        B, S, H, hd, W = 1, 256, 2, 32, 64
        q = _rand(ks[0], (B, S, H, hd), jnp.float32)
        k = _rand(ks[1], (B, S, H, hd), jnp.float32)
        v = _rand(ks[2], (B, S, H, hd), jnp.float32)
        got = flash_attention_fwd(q, k, v, causal=True, window=W,
                                  block_q=64, block_kv=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=W)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_matches_model_layer(self):
        """The model's scan formulation and the kernel agree."""
        from repro.models.layers import flash_attention as model_flash
        ks = jax.random.split(jax.random.key(2), 3)
        B, S, H, hd = 2, 128, 4, 32
        q = _rand(ks[0], (B, S, H, hd), jnp.float32)
        k = _rand(ks[1], (B, S, H, hd), jnp.float32)
        v = _rand(ks[2], (B, S, H, hd), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        a = model_flash(q, k, v, pos, pos, causal=True, kv_chunk=64)
        b = flash_attention_fwd(q, k, v, causal=True, block_q=64,
                                block_kv=64, interpret=True)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


class TestNearDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,Hkv,hd,T", [
        (2, 4, 2, 64, 256),
        (1, 8, 8, 32, 128),
        (3, 6, 2, 128, 128),
    ])
    def test_stats_against_ref(self, dtype, B, H, Hkv, hd, T):
        ks = jax.random.split(jax.random.key(3), 4)
        q = _rand(ks[0], (B, H, hd), dtype)
        k = _rand(ks[1], (B, T, Hkv, hd), dtype)
        v = _rand(ks[2], (B, T, Hkv, hd), dtype)
        length = jax.random.randint(ks[3], (B,), 1, T + 1)
        out, m, l = near_decode_attention(q, k, v, length, block_kv=64,
                                          interpret=True)
        want_out, want_m, want_l = ref.decode_attention_stats_ref(
            q[:, None], k, v, length)
        np.testing.assert_allclose(np.asarray(m), np.asarray(want_m),
                                   **TOL[dtype])
        np.testing.assert_allclose(np.asarray(l), np.asarray(want_l),
                                   rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4)
        # compare normalized outputs (unnormalized scale is implementation-defined)
        np.testing.assert_allclose(
            np.asarray(out / np.maximum(np.asarray(l)[..., None], 1e-30)),
            np.asarray(want_out / np.maximum(np.asarray(want_l)[..., None],
                                             1e-30)),
            **TOL[dtype])

    def test_two_tier_merge_equals_monolithic(self):
        """Near+far tiers with LSE merge == attention over the concatenation —
        the correctness property of the TL-DRAM read path."""
        ks = jax.random.split(jax.random.key(4), 5)
        B, H, Hkv, hd, Tn, Tf = 2, 4, 2, 32, 128, 192
        q = _rand(ks[0], (B, H, hd), jnp.float32)
        kn = _rand(ks[1], (B, Tn, Hkv, hd), jnp.float32)
        vn = _rand(ks[2], (B, Tn, Hkv, hd), jnp.float32)
        kf = _rand(ks[3], (B, Tf, Hkv, hd), jnp.float32)
        vf = _rand(ks[4], (B, Tf, Hkv, hd), jnp.float32)
        n_len = jnp.array([128, 64], jnp.int32)
        f_len = jnp.array([192, 100], jnp.int32)

        got = ops.tiered_decode_attention(q, kn, vn, n_len, kf, vf, f_len,
                                          block_kv=64)

        # monolithic: concatenate live prefixes per batch element
        outs = []
        for b in range(B):
            kcat = jnp.concatenate([kn[b, :n_len[b]], kf[b, :f_len[b]]])[None]
            vcat = jnp.concatenate([vn[b, :n_len[b]], vf[b, :f_len[b]]])[None]
            o = ref.decode_attention_ref(
                q[b:b + 1, None], kcat, vcat,
                jnp.array([kcat.shape[1]], jnp.int32))
            outs.append(o[0, 0])
        want = jnp.stack(outs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def _walk_meta(key, B, P, page, n_pages, C):
    """Random but well-formed walk/near metadata for the fused kernel:
    distinct pool ids per slot, live counts in [1, page] (partial last
    pages included), near live counts in [0, page] (0 = non-tenant)."""
    ks = jax.random.split(key, 4)
    walk_len = jax.random.randint(ks[0], (B,), 0, n_pages + 1)
    pid = jnp.stack([jax.random.permutation(k, P)[:n_pages]
                     for k in jax.random.split(ks[1], B)]).astype(jnp.int32)
    walk_live = jax.random.randint(ks[2], (B, n_pages), 1, page + 1)
    near_live = jax.random.randint(ks[3], (B, C), 0, page + 1)
    return pid, walk_live, walk_len.astype(jnp.int32), near_live


class TestPagedAttention:
    """Fused page-table-walking decode kernel vs its jnp oracle (ISSUE 4)."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,Hkv,hd,page,n_pages,C,P", [
        (2, 4, 2, 64, 16, 4, 2, 12),     # GQA
        (1, 8, 8, 32, 8, 6, 3, 10),      # MHA, more pages than near slots
        (3, 6, 2, 128, 32, 2, 4, 8),     # wide head, near > far
        (2, 2, 1, 16, 8, 5, 1, 16),      # MQA, tiny
    ])
    def test_against_ref(self, dtype, B, H, Hkv, hd, page, n_pages, C, P):
        ks = jax.random.split(jax.random.key(11), 6)
        q = _rand(ks[0], (B, H, hd), dtype)
        pool_k = _rand(ks[1], (P, page, Hkv, hd), dtype)
        pool_v = _rand(ks[2], (P, page, Hkv, hd), dtype)
        near_k = _rand(ks[3], (C * page, Hkv, hd), dtype)
        near_v = _rand(ks[4], (C * page, Hkv, hd), dtype)
        pid, walk_live, walk_len, near_live = _walk_meta(
            ks[5], B, P, page, n_pages, C)
        got = paged_attention(q, pool_k, pool_v, near_k, near_v, pid,
                              walk_live, walk_len, near_live, interpret=True)
        want = ref.paged_attention_ref(q, pool_k, pool_v, near_k, near_v,
                                       pid, walk_live, walk_len, near_live)
        # compare m, then normalized outputs (unnormalized scale is
        # implementation-defined between the two accumulation orders)
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                                   **TOL[dtype])
        g_out = np.asarray(got[0]) / np.maximum(np.asarray(got[2])[..., None],
                                                1e-30)
        w_out = np.asarray(want[0]) / np.maximum(
            np.asarray(want[2])[..., None], 1e-30)
        np.testing.assert_allclose(g_out, w_out, **TOL[dtype])

    def test_empty_walk_and_dead_near_yield_zero_mass(self):
        """A slot with nothing live (walk_len 0, all near_live 0) must
        produce l == 0 — the LSE merge then yields zeros, not NaNs."""
        B, H, Hkv, hd, page, C, P = 1, 2, 1, 16, 8, 2, 4
        ks = jax.random.split(jax.random.key(12), 5)
        q = _rand(ks[0], (B, H, hd), jnp.float32)
        pool = _rand(ks[1], (P, page, Hkv, hd), jnp.float32)
        near = _rand(ks[2], (C * page, Hkv, hd), jnp.float32)
        zeros2 = jnp.zeros((B, 3), jnp.int32)
        out, m, l = paged_attention(
            q, pool, pool, near, near, zeros2, zeros2,
            jnp.zeros((B,), jnp.int32), jnp.zeros((B, C), jnp.int32),
            interpret=True)
        assert np.all(np.asarray(l) == 0.0)
        assert np.all(np.asarray(out) == 0.0)
        merged = ref.merge_attention_stats([(out, m, l)])
        assert np.isfinite(np.asarray(merged)).all()

    @given(seed=st.integers(0, 99))
    @settings(max_examples=10, deadline=None)
    def test_property_random_meta(self, seed):
        B, H, Hkv, hd, page, n_pages, C, P = 2, 4, 2, 16, 8, 3, 2, 9
        ks = jax.random.split(jax.random.key(seed), 6)
        q = _rand(ks[0], (B, H, hd), jnp.float32)
        pool_k = _rand(ks[1], (P, page, Hkv, hd), jnp.float32)
        pool_v = _rand(ks[2], (P, page, Hkv, hd), jnp.float32)
        near_k = _rand(ks[3], (C * page, Hkv, hd), jnp.float32)
        near_v = _rand(ks[4], (C * page, Hkv, hd), jnp.float32)
        pid, walk_live, walk_len, near_live = _walk_meta(
            ks[5], B, P, page, n_pages, C)
        got = paged_attention(q, pool_k, pool_v, near_k, near_v, pid,
                              walk_live, walk_len, near_live, interpret=True)
        want = ref.paged_attention_ref(q, pool_k, pool_v, near_k, near_v,
                                       pid, walk_live, walk_len, near_live)
        g = np.asarray(got[0]) / np.maximum(np.asarray(got[2])[..., None],
                                            1e-30)
        w = np.asarray(want[0]) / np.maximum(np.asarray(want[2])[..., None],
                                             1e-30)
        np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-5)


class TestPagedGatherBudget:
    def test_pool_over_vmem_budget_raises(self):
        """ISSUE 4 satellite: the whole-pool-in-VMEM BlockSpec must refuse
        oversized pools with a clear error, not a silent docstring caveat."""
        pool = jnp.zeros((8, 16, 2, 16), jnp.float32)
        ids = jnp.zeros((1, 2), jnp.int32)
        with pytest.raises(ValueError, match="VMEM.*budget|budget"):
            paged_gather(pool, ids, interpret=True,
                         vmem_budget_bytes=pool.nbytes - 1)

    def test_pool_within_budget_runs(self):
        pool = jnp.arange(8 * 16 * 2 * 16, dtype=jnp.float32
                          ).reshape(8, 16, 2, 16)
        ids = jnp.asarray([[3, -1]], jnp.int32)
        got = paged_gather(pool, ids, interpret=True,
                           vmem_budget_bytes=pool.nbytes)
        want = ref.paged_gather_ref(pool, ids)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestTieredGather:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("T,C,D,bt", [(64, 16, 32, 32), (100, 8, 64, 64),
                                          (256, 128, 16, 128)])
    def test_against_ref(self, dtype, T, C, D, bt):
        ks = jax.random.split(jax.random.key(5), 3)
        near = _rand(ks[0], (C, D), dtype)
        far = _rand(ks[1], (T, D), dtype)
        slots = jax.random.randint(ks[2], (T,), -1, C)
        got = tiered_gather(near, slots, far, block_t=bt, interpret=True)
        want = ref.tiered_gather_ref(near, slots, far)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), rtol=0, atol=0)

    @given(t=st.integers(8, 96), c=st.integers(1, 32), seed=st.integers(0, 99))
    @settings(max_examples=20, deadline=None)
    def test_property_random_shapes(self, t, c, seed):
        ks = jax.random.split(jax.random.key(seed), 3)
        D = 8
        near = _rand(ks[0], (c, D), jnp.float32)
        far = _rand(ks[1], (t, D), jnp.float32)
        slots = jax.random.randint(ks[2], (t,), -1, c)
        got = tiered_gather(near, slots, far, block_t=32, interpret=True)
        want = ref.tiered_gather_ref(near, slots, far)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestSSDScan:
    @pytest.mark.parametrize("B,nc,H,P,N,bh", [
        (1, 4, 8, 16, 32, 4), (2, 8, 4, 8, 16, 4), (1, 16, 16, 32, 16, 8)])
    def test_against_ref(self, B, nc, H, P, N, bh):
        ks = jax.random.split(jax.random.key(6), 3)
        states = _rand(ks[0], (B, nc, H, P, N), jnp.float32)
        decays = jax.nn.sigmoid(_rand(ks[1], (B, nc, H), jnp.float32))
        h0 = _rand(ks[2], (B, H, P, N), jnp.float32)
        hp, hf = ssd_chunk_scan(states, decays, h0, block_h=bh, interpret=True)
        want_hp, want_hf = jax.vmap(ref.ssd_chunk_scan_ref)(states, decays, h0)
        np.testing.assert_allclose(np.asarray(hp), np.asarray(want_hp),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(want_hf),
                                   rtol=1e-6, atol=1e-6)
