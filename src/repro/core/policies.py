"""Compatibility shim — the near-segment policies now live in ``repro.tier``.

The object/dict implementations formerly defined here moved verbatim to
`repro.tier.reference`, where they serve as the parity oracle for the
vectorized engines (`repro.tier.engine` for the DRAM simulator's nanosecond
substrate, `repro.tier.jax_engine` for the TPU runtime — the tiered KV cache
in `repro.core.tiered_kv` and the tiered embedding in
`repro.core.tiered_embedding`).  See docs/tier.md.
"""

from __future__ import annotations

from repro.tier.reference import (  # noqa: F401
    POLICIES,
    BenefitBasedCaching,
    CacheState,
    Decision,
    Policy,
    PolicyCosts,
    SimpleCaching,
    StaticProfile,
    WaitMinimizedCaching,
    make_policy,
)
