"""Radix prefix cache: a trie over token prefixes at KV-page granularity.

The TL-DRAM premise — a small near segment pays off because accesses
concentrate on a few hot rows — holds for serving traffic at the *prefix*
level: the hottest KV "rows" are the shared prompt prefixes (system
prompts, few-shot headers, multi-turn history) that a slot-private cache
re-prefills and re-stores per tenant.  This index maps full prompt pages to
pages of the shared far pool (``repro.core.tiered_kv.PagePool``):

  match  : walk the trie page-by-page along a new prompt; every matched
           node's pool page is reused by the admitting slot (refcount++)
           and only the unmatched suffix is prefilled — the modeled clock
           and the real compute both drop.
  insert : after prefill, the prompt's full pages are cached under their
           pool ids (``PagePool.retain``): they survive the owning slots'
           retirement at refcount zero, so re-arrivals (multi-turn chat)
           hit them — the near-tier copy made for the first tenant keeps
           serving every later one.
  evict  : under pool pressure, least-recently-matched *leaf* pages with
           refcount zero are dropped (leaf-first keeps the invariant that a
           cached page's whole prefix chain is cached).

Matching is capped so at least one prompt token is always left for the
suffix prefill — the admission path needs last-position logits to emit the
first token.

Host-side by design: admissions are scheduler events (a few per tick), not
per-token work, and the device-side page tables only consume the resulting
page ids.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.tiered_kv import PagePool


@dataclass
class PrefixStats:
    lookups: int = 0
    hits: int = 0                 # lookups that matched >= 1 page
    hit_tokens: int = 0           # prompt tokens served from cached pages
    lookup_tokens: int = 0        # total prompt tokens seen by match()
    inserts: int = 0              # pages newly cached
    evictions: int = 0            # pages evicted under pool pressure

    @property
    def hit_rate(self) -> float:
        """Fraction of prompt tokens whose KV came from the cache."""
        return self.hit_tokens / max(self.lookup_tokens, 1)


class _Node:
    __slots__ = ("key", "page", "children", "parent", "last_use")

    def __init__(self, key, page, parent):
        self.key = key            # tuple of page-length token ids
        self.page = page          # pool page id holding this page's KV
        self.children: dict = {}
        self.parent = parent
        self.last_use = 0


class RadixPrefixCache:
    """Page-granular radix index over prompt prefixes, bound to a PagePool."""

    def __init__(self, pool: PagePool, page: int):
        self.pool = pool
        self.page = page
        self.root = _Node(None, -1, None)
        self.stats = PrefixStats()
        self._tick = 0
        self._n_nodes = 0

    # -- lookup --------------------------------------------------------------

    def _page_key(self, tokens, j: int):
        return tuple(int(t) for t in tokens[j * self.page:(j + 1) * self.page])

    def match(self, tokens) -> list[int]:
        """Longest cached full-page prefix of ``tokens``; returns the pool
        page ids, leaving >= 1 token for the suffix prefill."""
        self._tick += 1
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(tokens)
        limit = (len(tokens) - 1) // self.page
        node, out = self.root, []
        for j in range(limit):
            child = node.children.get(self._page_key(tokens, j))
            if child is None:
                break
            child.last_use = self._tick
            out.append(child.page)
            node = child
        if out:
            self.stats.hits += 1
            self.stats.hit_tokens += len(out) * self.page
        return out

    # -- insertion -----------------------------------------------------------

    def insert(self, tokens, page_ids) -> list[int]:
        """Cache the full pages of ``tokens`` under ``page_ids`` (one pool id
        per page).  Pages already cached keep their existing pool id (the
        caller's copy stays slot-private); returns the ids newly retained."""
        node, inserted = self.root, []
        for j in range(len(tokens) // self.page):
            key = self._page_key(tokens, j)
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(page_ids[j]), node)
                node.children[key] = child
                self.pool.retain([child.page])
                inserted.append(child.page)
                self._n_nodes += 1
                self.stats.inserts += 1
            child.last_use = self._tick
            node = child
        return inserted

    # -- allocation under pressure -------------------------------------------

    def allocate(self, n: int) -> tuple[list[int], list[int]]:
        """Allocate n pool pages, evicting LRU cached-idle leaves as needed.

        Returns (pages, evicted): the caller must reset tier state for the
        evicted page ids (their near-tier copies are stale the moment the
        ids are reused)."""
        evicted = []
        while self.pool.available() < n:
            victim = self._lru_evictable_leaf()
            if victim is None:
                raise RuntimeError(
                    "page pool exhausted and nothing evictable: "
                    f"want {n}, free {self.pool.available()}")
            evicted.extend(self._evict(victim))
        return self.pool.allocate(n), evicted

    def _lru_evictable_leaf(self):
        best = None
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if (node is not self.root and not node.children
                    and self.pool.refcount[node.page] == 0
                    and (best is None or node.last_use < best.last_use)):
                best = node
        return best

    def _evict(self, node: _Node) -> list[int]:
        del node.parent.children[node.key]
        self._n_nodes -= 1
        self.stats.evictions += 1
        return self.pool.drop_cached([node.page])

    # -- consistency ----------------------------------------------------------

    def cached_pages(self) -> set[int]:
        """The pool page ids the trie currently retains — with the pool as
        the single source of truth this must equal exactly the pool's
        ``cached`` flag set (the engine's shutdown sweep asserts it; a
        divergence means an insert/evict path leaked a retention flag)."""
        out: set[int] = set()
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is not self.root:
                out.add(int(node.page))
        return out

    def __len__(self) -> int:
        return self._n_nodes
