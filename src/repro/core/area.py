"""DRAM die-area / cost model.

Each sense amplifier is ~100x the size of a cell [107], so the die area of a
DRAM with ``n`` cells per bitline amortizes the sense-amp stripe over ``n``
rows.  Normalized die size (Table 1 / Fig. 3 of the paper):

    A(n) = a + b / n        a = cell array + periphery,  b = sense-amp stripe

anchored at A(512) = 1.00 (commodity long bitline) and A(32) = 3.76
(short-bitline latency-optimized part, e.g. RLDRAM).

TL-DRAM keeps the long bitline's single sense-amp stripe and adds one
isolation transistor per bitline: +3% die area per added tier boundary
(paper: segmented = 1.03).
"""

from __future__ import annotations

CELLS_PER_BITLINE_BASELINE = 512
AREA_LONG = 1.00
AREA_SHORT_32 = 3.76
ISO_OVERHEAD_PER_TIER = 0.03

# Solve a + b/512 = 1.00, a + b/32 = 3.76.
_B = (AREA_SHORT_32 - AREA_LONG) / (1.0 / 32 - 1.0 / CELLS_PER_BITLINE_BASELINE)
_A = AREA_LONG - _B / CELLS_PER_BITLINE_BASELINE


def die_area_norm(cells_per_bitline: int) -> float:
    """Normalized die area of an *unsegmented* DRAM (commodity-512 == 1.0)."""
    if cells_per_bitline <= 0:
        raise ValueError("cells_per_bitline must be positive")
    return _A + _B / cells_per_bitline


def tldram_area_norm(total_cells: int = CELLS_PER_BITLINE_BASELINE,
                     tiers: int = 2) -> float:
    """TL-DRAM die area: long-bitline cost plus iso-FET overhead per boundary."""
    if tiers < 2:
        raise ValueError("TL-DRAM needs at least 2 tiers")
    return die_area_norm(total_cells) + ISO_OVERHEAD_PER_TIER * (tiers - 1)


def cost_per_bit_norm(cells_per_bitline: int) -> float:
    """Cost-per-bit tracks die area at fixed capacity."""
    return die_area_norm(cells_per_bitline)


def table1_area_norm() -> dict[str, float]:
    """Reproduces the 'Normalized Die-Size (Cost)' row of Table 1."""
    return {
        "short_32": die_area_norm(32),
        "long_512": die_area_norm(512),
        "segmented": tldram_area_norm(512, tiers=2),
    }


def fig3_tradeoff(cells: tuple[int, ...] = (32, 64, 128, 256, 512)) -> dict[int, dict]:
    """Fig. 3: latency vs die size for different cells-per-bitline choices."""
    from repro.core import tldram  # local import: avoid cycle at module load

    out = {}
    for n in cells:
        t = tldram.calibrated_timings("unsegmented", n)
        out[n] = {"t_rcd_ns": t.t_rcd, "t_rc_ns": t.t_rc,
                  "die_area_norm": die_area_norm(n)}
    return out
