"""AdamW with optional 8-bit (block-quantized) moments.

The 8-bit mode stores both moments as int8 with per-block f32 absmax scales
(block = 256 elements, following the 8-bit-optimizers recipe) — a 3.5x
reduction of optimizer-state HBM, which is what lets the trillion-parameter
config fit a 512-chip fleet (docs/experiments.md §Dry-run).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

QBLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "f32"      # 'f32' | 'int8'


# -- block quantization --------------------------------------------------------

def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


# -- state ----------------------------------------------------------------------

def init(params, cfg: AdamWConfig):
    def zeros_like_moment(p):
        if cfg.moment_dtype == "int8":
            q, s = _quantize(jnp.zeros_like(p, jnp.float32))
            return {"q": q, "scale": s}
        return jnp.zeros_like(p, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),
    }


def _read_moment(mom, like, cfg: AdamWConfig, kind: str = "m"):
    if cfg.moment_dtype == "int8":
        val = _dequantize(mom["q"], mom["scale"], like.shape)
        if kind == "v":      # v is stored in sqrt-space (8-bit-Adam recipe):
            return jnp.square(val)   # compresses the dynamic range ~2x in log
        return val
    return mom


def _write_moment(val, cfg: AdamWConfig, kind: str = "m"):
    if cfg.moment_dtype == "int8":
        if kind == "v":
            val = jnp.sqrt(jnp.maximum(val, 0.0))
        q, s = _quantize(val)
        return {"q": q, "scale": s}
    return val


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(params, grads, state, cfg: AdamWConfig, lr: jax.Array | float):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _read_moment(m, p, cfg, "m")
        v_f = _read_moment(v, p, cfg, "v")
        m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_new = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms/biases/scalars
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return (p_new, _write_moment(m_new, cfg, "m"),
                _write_moment(v_new, cfg, "v"))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [leaf(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, {"grad_norm": gnorm}


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
