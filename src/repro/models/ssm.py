"""Mamba-2 SSD (state-space duality) mixer, pure JAX.

Chunked algorithm of Dao & Gu (arXiv:2405.21060): within a chunk of length Q
the recurrence is computed in its "attention-like" dual form (quadratic in Q),
while chunk-level states are carried by a linear recurrence over chunks —
O(S*Q) work and O(S/Q) sequential steps instead of O(S) — which is what makes
the 500k-token shapes tractable.

Decode is the O(1) recurrent form: one state update per token, no KV cache —
the reason the TL-DRAM KV-tier mechanism is inapplicable to this family
(docs/design.md §Arch-applicability).

Layout: x (B,S,H,P) heads; B/C projections shared across heads (one group);
state (B,H,P,N).  All recurrence math in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import rms_norm


def d_inner(cfg: SSMConfig) -> int:
    return cfg.n_heads * cfg.head_dim


def conv_dim(cfg: SSMConfig) -> int:
    return d_inner(cfg) + 2 * cfg.d_state


def init_ssm_params(key: jax.Array, d_model: int, cfg: SSMConfig,
                    dtype=jnp.float32) -> dict:
    """Projections are separate leaves (not one packed in_proj) so each can
    carry its own PartitionSpec: z/x shard head-aligned over 'model', B/C/dt
    stay replicated (tiny) — see sharding/specs.py."""
    ks = jax.random.split(key, 7)
    di = d_inner(cfg)
    N, H = cfg.d_state, cfg.n_heads
    scale = d_model ** -0.5
    return {
        "in_z": (jax.random.normal(ks[0], (d_model, di)) * scale).astype(dtype),
        "in_x": (jax.random.normal(ks[1], (d_model, di)) * scale).astype(dtype),
        "in_B": (jax.random.normal(ks[2], (d_model, N)) * scale).astype(dtype),
        "in_C": (jax.random.normal(ks[3], (d_model, N)) * scale).astype(dtype),
        "in_dt": (jax.random.normal(ks[4], (d_model, H)) * scale).astype(dtype),
        "conv_x_w": (jax.random.normal(ks[5], (cfg.d_conv, di)) * 0.2
                     ).astype(dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": (jax.random.normal(ks[6], (cfg.d_conv, 2 * N)) * 0.2
                      ).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * N,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[0], (di, d_model)) * di ** -0.5
                     ).astype(dtype),
    }


def _split_proj(params, x, cfg: SSMConfig):
    """x: (B,S,D) -> z (B,S,di), xs (B,S,di), bc (B,S,2N), dt (B,S,H)."""
    z = jnp.einsum("bsd,dp->bsp", x, params["in_z"])
    xs = jnp.einsum("bsd,dp->bsp", x, params["in_x"])
    bc = jnp.einsum("bsd,dp->bsp", x,
                    jnp.concatenate([params["in_B"], params["in_C"]], axis=1))
    dt = jnp.einsum("bsd,dp->bsp", x, params["in_dt"])
    return z, xs, bc, dt


def _causal_conv(xbc, conv_w, conv_b, history=None):
    """Depthwise causal conv1d over S.  history: (B, d_conv-1, cd) or None.

    Returns (activated output, padded input buffer) — the caller slices the
    conv tail out of ``padded`` at the last *real* position."""
    d_conv = conv_w.shape[0]
    if history is None:
        history = jnp.zeros((xbc.shape[0], d_conv - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([history, xbc], axis=1)
    S = xbc.shape[1]
    out = sum(padded[:, i:i + S] * conv_w[i] for i in range(d_conv))
    return jax.nn.silu(out + conv_b), padded


def ssd_chunked(params: dict, x: jax.Array, cfg: SSMConfig,
                initial_state: jax.Array | None = None,
                conv_history: jax.Array | None = None):
    """Training/prefill pass.  x: (B,S,D).

    Returns (y (B,S,D), final_state (B,H,P,N) f32, conv_tail (B,d_conv-1,cd)).
    """
    B, S_real, D = x.shape
    H, P, N, Q = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.chunk
    # Pad to a chunk multiple with identity steps (dt = 0 => decay 1, no
    # input contribution), so outputs at real positions and the final state
    # are exact for any sequence length.
    pad = (-S_real) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    S = S_real + pad
    nc = S // Q

    z, xs_raw, bc_raw, dt = _split_proj(params, x, cfg)
    if conv_history is None:
        hist_x = hist_bc = None
    else:
        hist_x, hist_bc = conv_history
    xs_act, conv_x_pad = _causal_conv(xs_raw, params["conv_x_w"],
                                      params["conv_x_b"], hist_x)
    bc_act, conv_bc_pad = _causal_conv(bc_raw, params["conv_bc_w"],
                                       params["conv_bc_b"], hist_bc)
    di = d_inner(cfg)
    d_conv = params["conv_x_w"].shape[0]
    # conv history for the next segment: window ending at the last REAL token.
    conv_tail = (
        jax.lax.dynamic_slice_in_dim(conv_x_pad, S_real, d_conv - 1, axis=1),
        jax.lax.dynamic_slice_in_dim(conv_bc_pad, S_real, d_conv - 1, axis=1))
    xs = xs_act.reshape(B, S, H, P).astype(jnp.float32)
    B_ssm = bc_act[..., :N].astype(jnp.float32)
    C_ssm = bc_act[..., N:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    if pad:
        live = (jnp.arange(S) < S_real).astype(jnp.float32)
        dt = dt * live[None, :, None]
    A = -jnp.exp(params["a_log"])                                      # (H,)
    dA = dt * A                                                        # (B,S,H) <= 0
    xdt = xs * dt[..., None]                                           # (B,S,H,P)

    # chunked views
    dA_c = dA.reshape(B, nc, Q, H)
    l = jnp.cumsum(dA_c, axis=2)                                       # (B,nc,Q,H)
    Bc = B_ssm.reshape(B, nc, Q, N)
    Cc = C_ssm.reshape(B, nc, Q, N)
    xdt_c = xdt.reshape(B, nc, Q, H, P)

    # --- intra-chunk (dual quadratic form) ---
    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]
    # decay(i,j) = exp(l_i - l_j) for i >= j
    decay = jnp.exp(jnp.clip(l[:, :, :, None, :] - l[:, :, None, :, :],
                             -60.0, 0.0))                              # (B,nc,Q,Q,H)
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                         # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, xdt_c)

    # --- chunk states and inter-chunk recurrence ---
    # seg[j] = exp(l_last - l_j); the exponent is a sum of dA <= 0 terms.
    seg = jnp.exp(jnp.clip(l[:, :, -1, None, :] - l, -60.0, 0.0))      # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, seg, xdt_c)      # (B,nc,H,P,N)
    chunk_decay = jnp.exp(jnp.clip(l[:, :, -1, :], -60.0, 0.0))        # (B,nc,H)

    h0 = (initial_state if initial_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32))

    def body(h, inp):
        st, dec = inp                                     # (B,H,P,N), (B,H)
        h_next = h * dec[:, :, None, None] + st
        return h_next, h                                  # emit state *before* chunk

    h_final, h_prev = jax.lax.scan(
        body, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)              # (B,nc,H,P,N)

    inner_decay = jnp.exp(jnp.clip(l, -60.0, 0.0))        # exp(l_i)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, h_prev, inner_decay)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + params["d_skip"][None, None, :, None] * xs
    y = y.reshape(B, S, di)

    # gated RMSNorm + output projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm_scale"])
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    if pad:
        out = out[:, :S_real]
    return out, h_final, conv_tail


def ssd_decode_step(params: dict, x: jax.Array, state: jax.Array,
                    conv_state: tuple, cfg: SSMConfig):
    """One-token recurrent step.  x: (B,1,D); state: (B,H,P,N) f32;
    conv_state: ((B,d_conv-1,di), (B,d_conv-1,2N)).
    Returns (y (B,1,D), state, conv_state)."""
    B = x.shape[0]
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.d_state

    z, xs_raw, bc_raw, dt = _split_proj(params, x, cfg)
    hist_x, hist_bc = conv_state
    xs_act, conv_x_pad = _causal_conv(xs_raw, params["conv_x_w"],
                                      params["conv_x_b"], hist_x)
    bc_act, conv_bc_pad = _causal_conv(bc_raw, params["conv_bc_w"],
                                       params["conv_bc_b"], hist_bc)
    conv_state = (conv_x_pad[:, 1:], conv_bc_pad[:, 1:])   # drop oldest slot
    xs = xs_act[:, -1].reshape(B, H, P).astype(jnp.float32)
    bc = bc_act[:, -1]
    B_ssm = bc[..., :N].astype(jnp.float32)                # (B,N)
    C_ssm = bc[..., N:].astype(jnp.float32)

    di = d_inner(cfg)
    dt = jax.nn.softplus(dt[:, -1].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    dA = jnp.exp(dt * A)                                   # (B,H)
    state = state * dA[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xs * dt[..., None], B_ssm)
    y = jnp.einsum("bhpn,bn->bhp", state, C_ssm)
    y = y + params["d_skip"][None, :, None] * xs
    y = y.reshape(B, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm_scale"])
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    return out, state, conv_state
