"""Bench-regression gate: diff a fresh serving-bench run against the
committed BENCH_serving.json (ISSUE 5 satellite).

Fails when, for any (scenario, policy) cell present in both files:

  * modeled throughput (``tok/kcost_modeled`` — the deterministic,
    machine-independent tokens-per-cost column) regresses by more than
    ``--tol`` (default 10%), or
  * modeled ``p99_lat`` or ``p50_ttft`` GROWS by more than ``--tol``
    (lower is better — the ISSUE 8 tail-latency wins are gated, not just
    reported), or
  * ``kv_bytes_live`` grows AT ALL (any memory growth is a regression:
    the pool-native engine's whole point is that live KV tracks demand).

Additionally the ``mesh_scaling`` acceptance cell's ``tok_per_kcost*``
keys (single-lane, fleet, per-device — ISSUE 10) regress like matrix
throughput: a > ``--tol`` drop in modeled tokens/cost-per-device fails.

Wall-clock tokens/s is also diffed but only *warns* by default — CI
runners and dev machines differ by integer factors, so a wall gate would
flap; pass ``--strict-wall`` to enforce it on a pinned machine.  The
acceptance cells (speedup, kv_live_ratio <= 0.6, far-rows parity) are
asserted inside ``serving_bench.run_all`` itself, so simply completing the
fresh run re-proves them.

  PYTHONPATH=src python -m benchmarks.check_bench_regression --run
  PYTHONPATH=src python -m benchmarks.check_bench_regression \
      --new /tmp/BENCH_serving.json            # diff two existing files
"""

from __future__ import annotations

import argparse
import json
import sys


def _cells(doc: dict) -> dict:
    return {(r["scenario"], r["policy"]): r for r in doc.get("matrix", [])}


def compare(old: dict, new: dict, tol: float = 0.10,
            strict_wall: bool = False) -> list[str]:
    """Returns the list of failure strings (empty == gate passes)."""
    failures, warnings = [], []
    old_cells, new_cells = _cells(old), _cells(new)
    shared = sorted(set(old_cells) & set(new_cells))
    if not shared:
        return ["no common (scenario, policy) cells between the committed "
                "and fresh BENCH_serving.json — header drift?"]
    for key in shared:
        o, n = old_cells[key], new_cells[key]
        o_thr = float(o.get("tok/kcost_modeled", 0.0))
        n_thr = float(n.get("tok/kcost_modeled", 0.0))
        if o_thr > 0 and n_thr < o_thr * (1.0 - tol):
            failures.append(
                f"{key}: modeled throughput {n_thr:.3f} < "
                f"{(1 - tol):.0%} of committed {o_thr:.3f}")
        for col in ("p99_lat", "p50_ttft"):     # modeled, deterministic;
            o_lat = float(o.get(col, 0.0))      # LOWER is better (unlike
            n_lat = float(n.get(col, 0.0))      # the throughput columns)
            if o_lat > 0 and n_lat > o_lat * (1.0 + tol):
                failures.append(
                    f"{key}: {col} {n_lat:.1f} > "
                    f"{(1 + tol):.0%} of committed {o_lat:.1f}")
        if "kv_bytes_live" in o:       # absent in pre-ISSUE-5 baselines
            o_kv = int(o["kv_bytes_live"])
            n_kv = int(n.get("kv_bytes_live", 0))
            if n_kv > o_kv:
                failures.append(
                    f"{key}: kv_bytes_live grew {o_kv} -> {n_kv} "
                    f"(any growth fails)")
        o_wall = float(o.get("tok/s_wall", 0.0))
        n_wall = float(n.get("tok/s_wall", 0.0))
        if o_wall > 0 and n_wall < o_wall * (1.0 - tol):
            msg = (f"{key}: wall tokens/s {n_wall:.1f} < "
                   f"{(1 - tol):.0%} of committed {o_wall:.1f}")
            (failures if strict_wall else warnings).append(msg)
    # ISSUE 10: the mesh-scaling acceptance cell's modeled-throughput
    # keys (single-lane, fleet, AND per-device — the column that catches
    # "more lanes hiding a slower engine") gate exactly like matrix cells.
    o_mesh = old.get("cells", {}).get("mesh_scaling", {})
    n_mesh = new.get("cells", {}).get("mesh_scaling", {})
    for key in sorted(set(o_mesh) & set(n_mesh)):
        if not key.startswith("tok_per_kcost"):
            continue
        o_thr, n_thr = float(o_mesh[key]), float(n_mesh[key])
        if o_thr > 0 and n_thr < o_thr * (1.0 - tol):
            failures.append(
                f"mesh_scaling/{key}: modeled throughput {n_thr:.3f} < "
                f"{(1 - tol):.0%} of committed {o_thr:.3f}")
    for w in warnings:
        print(f"WARN (wall clock, not gated): {w}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--old", default="BENCH_serving.json",
                    help="committed bench file (the baseline)")
    ap.add_argument("--new", default=None,
                    help="fresh bench file to gate (default: produced by "
                         "--run)")
    ap.add_argument("--run", action="store_true",
                    help="run serving_bench.run_all to produce the fresh "
                         "file first")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="fractional tokens/s regression tolerance")
    ap.add_argument("--strict-wall", action="store_true",
                    help="gate wall-clock tokens/s too (pinned machines)")
    args = ap.parse_args(argv)

    with open(args.old) as f:
        old = json.load(f)
    new_path = args.new
    if args.run:
        from benchmarks import serving_bench
        new_path = new_path or "/tmp/BENCH_serving_fresh.json"
        serving_bench.run_all(out_path=new_path)
    if new_path is None:
        ap.error("need --new FILE or --run")
    with open(new_path) as f:
        new = json.load(f)

    failures = compare(old, new, tol=args.tol, strict_wall=args.strict_wall)
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}")
        return 1
    print(f"bench regression gate passed over "
          f"{len(set(_cells(old)) & set(_cells(new)))} cells")
    return 0


if __name__ == "__main__":
    sys.exit(main())
