"""DRAM system simulator tests: paper claims + structural invariants."""

import numpy as np
import pytest

from repro.core import area, power, simulator as S, timing, traces as T


def _run_pair(mix, n=8000, seed=1, policy="BBC", near=32):
    tr = T.make_mix(mix, n_requests=n, seed=seed)
    base = S.simulate(S.SimConfig(device=S.DeviceConfig(kind="commodity")), tr)
    tl = S.simulate(S.SimConfig(
        device=S.DeviceConfig(kind="tldram", policy=policy, near_rows=near)), tr)
    return base, tl


class TestPaperClaims:
    def test_hot_workload_improves_ipc(self):
        base, tl = _run_pair(("hot",))
        assert tl.cores[0].ipc > base.cores[0].ipc * 1.05

    def test_hot_workload_saves_energy_and_power(self):
        base, tl = _run_pair(("hot",))
        assert tl.energy_nj < base.energy_nj
        assert tl.power_mw < base.power_mw

    def test_near_hit_rate_over_90pct_on_locality_workloads(self):
        """Paper Sec. 5: 'over 90% on average of requests hit in the rows
        cached in the near segment' under BBC."""
        rates = []
        for m, s in (("hot", 1), ("hot2", 2), ("light", 3)):
            _, tl = _run_pair((m,), seed=s)
            rates.append(tl.near_hit_rate)
        assert np.mean(rates) > 0.90

    def test_short_bitline_device_is_fastest(self):
        tr = T.make_mix(("hot",), n_requests=6000, seed=0)
        base = S.simulate(S.SimConfig(device=S.DeviceConfig(kind="commodity")), tr)
        short = S.simulate(S.SimConfig(
            device=S.DeviceConfig(kind="short", near_rows=32)), tr)
        assert short.cores[0].ipc > base.cores[0].ipc

    def test_static_profile_policy_works(self):
        base, tl = _run_pair(("hot",), policy="STATIC")
        assert tl.migrations == 0
        assert tl.near_hit_rate > 0.5
        assert tl.cores[0].ipc > base.cores[0].ipc

    def test_multicore_runs_and_improves(self):
        base, tl = _run_pair(("hot", "mixed"), n=5000)
        assert len(base.cores) == 2
        assert sum(c.ipc for c in tl.cores) > sum(c.ipc for c in base.cores)

    def test_weighted_speedup(self):
        tr = T.make_mix(("hot", "mixed"), n_requests=4000, seed=2)
        cfg = S.SimConfig(device=S.DeviceConfig(kind="commodity"))
        shared = S.simulate(cfg, tr)
        alone = S.simulate_alone(cfg, tr)
        ws = shared.weighted_speedup(alone)
        assert 0.2 < ws <= 2.0 + 1e-9  # per-core slowdown under sharing


class TestISTChannelFree:
    """Inter-segment transfer occupies the bank, never the channel: accesses
    to *other* banks proceed during a migration (paper Sec. 4)."""

    def test_migration_does_not_block_other_banks(self):
        # Two cores, disjoint banks; core0's workload triggers migrations.
        n = 3000
        rng = np.random.default_rng(0)
        hot = T.Trace(
            gaps=np.full(n, 10), banks=np.zeros(n, dtype=np.int64),
            subarrays=np.zeros(n, dtype=np.int64),
            rows=rng.integers(0, 8, size=n),
            writes=np.zeros(n, dtype=bool))
        other = T.Trace(
            gaps=np.full(n, 10), banks=np.full(n, 3, dtype=np.int64),
            subarrays=np.zeros(n, dtype=np.int64),
            rows=rng.integers(0, 8, size=n),
            writes=np.zeros(n, dtype=bool))
        cfg_tl = S.SimConfig(device=S.DeviceConfig(kind="tldram", policy="SC"))
        both = S.simulate(cfg_tl, [hot, other])
        assert both.migrations > 0
        solo = S.simulate(cfg_tl, [other])
        # Core on bank 3 is unaffected by migrations on bank 0 beyond generic
        # channel sharing: its IPC stays within 15% of running alone.
        assert both.cores[1].ipc > solo.cores[0].ipc * 0.85

    def test_ist_duration_matches_paper(self):
        near, far = timing.tldram_timings(32)
        assert timing.ist_duration_ns(far) == pytest.approx(far.t_rc + 4.0)


class TestDeterminism:
    def test_same_seed_same_result(self):
        a = _run_pair(("mixed",), n=2000, seed=7)[1]
        b = _run_pair(("mixed",), n=2000, seed=7)[1]
        assert a.energy_nj == b.energy_nj
        assert [c.ipc for c in a.cores] == [c.ipc for c in b.cores]


class TestPowerModel:
    def test_table1_power_row(self):
        p = power.table1_power_norm()
        assert p["short_32"] == pytest.approx(0.51, abs=0.005)
        assert p["long_512"] == pytest.approx(1.00, abs=0.005)
        assert p["near_32"] == pytest.approx(0.51, abs=0.005)
        assert p["far_480"] == pytest.approx(1.49, abs=0.005)


class TestAreaModel:
    def test_table1_area_row(self):
        a = area.table1_area_norm()
        assert a["short_32"] == pytest.approx(3.76, abs=0.005)
        assert a["long_512"] == pytest.approx(1.00, abs=0.005)
        assert a["segmented"] == pytest.approx(1.03, abs=0.005)

    def test_area_decreases_with_cells_per_bitline(self):
        areas = [area.die_area_norm(n) for n in (32, 64, 128, 256, 512)]
        assert areas == sorted(areas, reverse=True)


class TestEnergyAccounting:
    def test_energy_components_positive_and_sum(self):
        _, tl = _run_pair(("hot",), n=3000)
        assert tl.energy_nj > 0
        assert tl.migrations >= 0
        acts = sum(tl.acts_by_class.values())
        assert acts > 0
        # every request either hit in near or was a far access
        assert tl.near_hits + tl.far_accesses == 3000
