"""Prefix-reuse parity (ISSUE 3 acceptance).

The load-bearing property: turning the radix prefix cache on changes the
*cost* of serving (fewer prefill tokens, better TTFT) and NEVER the tokens.
Identical traces through the sharing and non-sharing engines must emit
bit-identical outputs under all four tier policies, with a real prefix hit
rate on the chat scenario; on the shared-system-prompt scenario the sharing
engine must prefill >= 40% fewer tokens and improve modeled p50 TTFT.
"""

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer
from repro.serve import ServingConfig, ServingEngine
from repro.serve.trace import SCENARIOS


@pytest.fixture(scope="module")
def arch_params():
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(0), arch)
    return arch, params


def _cfg(policy: str, share: bool) -> ServingConfig:
    tier = TieredKVConfig(page=16, near_pages=4, interval=4, policy=policy)
    return ServingConfig(n_slots=3, max_len=96, prefill_bucket=16, tier=tier,
                         share_prefix=share, verify_tiered_read=True)


def _chat_trace(vocab: int):
    return SCENARIOS["multi_turn_chat"](vocab, n_sessions=2, turns=2,
                                        base_len=20, turn_len=12,
                                        max_new_tokens=6, think_gap=16)


class TestPrefixReuseParity:
    @pytest.mark.parametrize("policy", ["SC", "WMC", "BBC", "STATIC"])
    def test_chat_trace_bit_identical_across_policies(self, arch_params,
                                                      policy):
        arch, params = arch_params
        trace = _chat_trace(arch.vocab)
        base = ServingEngine(params, arch, _cfg(policy, False)).run(
            trace, "multi_turn_chat")
        share = ServingEngine(params, arch, _cfg(policy, True)).run(
            trace, "multi_turn_chat")
        assert base.outputs == share.outputs, \
            f"policy {policy}: sharing changed emitted tokens"
        # prefix hit rate > 0 on the chat scenario (acceptance)
        assert share.prefix_hit_tokens > 0
        assert share.prefix_hit_rate > 0
        assert share.prefix_hits > 0
        # sharing only ever removes prefill work
        assert share.prefill_tokens < share.prefill_tokens_full
        assert base.prefill_tokens == base.prefill_tokens_full
        # the paged read probe stayed at bf16 noise level in both engines
        assert base.max_read_err < 5e-2
        assert share.max_read_err < 5e-2

    @pytest.mark.parametrize("policy", ["SC", "WMC", "BBC", "STATIC"])
    def test_long_context_summarize_parity_across_policies(self, arch_params,
                                                           policy):
        """ISSUE 5 satellite: the long-document trace (few slots, very long
        shared prompts — the regime where a dense per-slot KV master hurt
        most) through the sharing and non-sharing pool-native engines:
        bit-identical tokens under every policy, real hits, and the
        sharing engine's live KV stays below the non-sharing engine's."""
        arch, params = arch_params
        trace = SCENARIOS["long_context_summarize"](
            arch.vocab, n_requests=4, doc_len=64, question_len=12,
            max_new_tokens=6, gap=3)
        base = ServingEngine(params, arch, _cfg(policy, False)).run(
            trace, "long_context_summarize")
        share = ServingEngine(params, arch, _cfg(policy, True)).run(
            trace, "long_context_summarize")
        assert base.outputs == share.outputs, \
            f"policy {policy}: sharing changed emitted tokens"
        assert share.prefix_hit_tokens > 0
        assert share.kv_bytes_live < base.kv_bytes_live, \
            "document sharing must shrink peak live KV bytes"

    def test_shared_system_prompt_savings_and_ttft(self, arch_params):
        """Acceptance cell: >= 40% fewer prefilled tokens and better modeled
        p50 TTFT on the shared-system-prompt trace, tokens bit-identical.
        (The full-size pinned version runs in benchmarks/serving_bench.py.)
        """
        arch, params = arch_params
        trace = SCENARIOS["shared_system_prompt"](
            arch.vocab, n_requests=6, sys_len=48, user_len=12,
            max_new_tokens=8, gap=2)
        base = ServingEngine(params, arch, _cfg("BBC", False)).run(
            trace, "shared_system_prompt")
        share = ServingEngine(params, arch, _cfg("BBC", True)).run(
            trace, "shared_system_prompt")
        assert base.outputs == share.outputs
        assert share.prefill_saved_frac >= 0.4, \
            f"only {share.prefill_saved_frac:.0%} prefill tokens saved"
        assert share.p50_ttft < base.p50_ttft, \
            (share.p50_ttft, base.p50_ttft)
        assert share.modeled_time < base.modeled_time

    def test_mixed_trace_parity_and_loner_isolation(self, arch_params):
        """Sharers win, loners are untaxed, outputs stay identical on the
        mixed scenario; a re-run of the SAME engine must also reset the
        prefix cache (fresh run state, reproducible reports)."""
        arch, params = arch_params
        trace = SCENARIOS["mixed_prefix"](arch.vocab, n_requests=6,
                                          sys_len=32, user_len=16,
                                          max_new_tokens=6, gap=3)
        base = ServingEngine(params, arch, _cfg("BBC", False)).run(
            trace, "mixed_prefix")
        eng = ServingEngine(params, arch, _cfg("BBC", True))
        share = eng.run(trace, "mixed_prefix")
        assert base.outputs == share.outputs
        assert share.prefix_hit_tokens > 0
        share2 = eng.run(trace, "mixed_prefix")
        assert share2.outputs == share.outputs
        assert share2.prefix_hit_tokens == share.prefix_hit_tokens
        assert share2.prefill_tokens == share.prefill_tokens
