"""Kimi K2: trillion-parameter MoE decoder (paper-table config).

[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8) expert_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared expert).
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163_840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared_experts=1),
    source="arXiv:2501.kimi2 (paper table); unverified",
)
