"""Arrival traces for the continuous-batching serving engine.

A trace is a list of :class:`Request` sorted by arrival tick.  Arrival
times are expressed in *engine decode-step ticks* (the engine's scheduling
quantum); the modeled byte-cost clock (``repro.serve.metrics``) is layered
on top by the engine itself, so traces stay independent of the cost model.

Scenario builders mirror the workload classes of the DRAM-side benchmark
suite (docs/design.md §2a): a steady Zipfian stream (the serving twin of the
paper's ``hot`` class), bursty arrivals (admission-control stress), a
long-context straggler mix (slot-pool fragmentation stress), and a shifting
hotspot (eviction/migration churn — the scenario that separates the four
tier policies the way the paper's Fig 8 does).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    rid: int
    arrival: int                  # engine step tick the request arrives at
    prompt: np.ndarray            # (S,) int32 token ids
    max_new_tokens: int


def _zipf_tokens(rng: np.random.Generator, vocab: int, n: int,
                 alpha: float = 1.3, head_offset: int = 0) -> np.ndarray:
    """Zipfian token draws: a small hot set dominates, like real prompt
    distributions.  ``head_offset`` rotates which tokens form the head
    (used by the shifting-hotspot scenario)."""
    ranks = np.arange(1, vocab + 1)
    p = ranks ** -alpha
    p /= p.sum()
    draws = rng.choice(vocab, size=n, p=p)
    return ((draws + head_offset) % vocab).astype(np.int32)


def steady_zipfian(vocab: int, n_requests: int = 12, prompt_len: int = 24,
                   max_new_tokens: int = 16, gap: int = 2,
                   seed: int = 0) -> list[Request]:
    """Steady arrivals (one every ``gap`` ticks), Zipfian prompt content —
    the scenario the >= 2x continuous-batching acceptance is measured on."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i, arrival=i * gap,
                    prompt=_zipf_tokens(rng, vocab, prompt_len),
                    max_new_tokens=max_new_tokens)
            for i in range(n_requests)]


def bursty(vocab: int, n_requests: int = 12, prompt_len: int = 24,
           max_new_tokens: int = 16, burst: int = 4, burst_gap: int = 20,
           seed: int = 1) -> list[Request]:
    """Whole bursts arrive at once, then silence: queueing delay shows up
    in first-token latency, and the slot pool oversubscribes."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i, arrival=(i // burst) * burst_gap,
                    prompt=_zipf_tokens(rng, vocab, prompt_len),
                    max_new_tokens=max_new_tokens)
            for i in range(n_requests)]


def long_context_stragglers(vocab: int, n_requests: int = 10,
                            prompt_len: int = 16, max_new_tokens: int = 12,
                            straggler_every: int = 4, long_factor: int = 4,
                            gap: int = 2, seed: int = 2) -> list[Request]:
    """Mostly short requests plus periodic long-prompt, long-generation
    stragglers that pin a slot for many ticks.  ``gap=1`` oversubscribes
    the slot pool so the median request queues behind the stragglers — the
    regime where synchronous admission prefill inflates everyone's TTFT."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        straggler = (i % straggler_every) == (straggler_every - 1)
        plen = prompt_len * (long_factor if straggler else 1)
        gen = max_new_tokens * (2 if straggler else 1)
        reqs.append(Request(rid=i, arrival=i * gap,
                            prompt=_zipf_tokens(rng, vocab, plen),
                            max_new_tokens=gen))
    return reqs


def shifting_hotspot(vocab: int, n_requests: int = 12, prompt_len: int = 24,
                     max_new_tokens: int = 16, gap: int = 2,
                     seed: int = 3, hot_len: int = 16,
                     drift_at: float = 0.5) -> list[Request]:
    """Every prompt starts with a shared hot head (the serving analogue of
    the paper's hottest-row concentration) whose identity ROTATES at
    ``drift_at`` of the stream: phase-2 requests share a *different* head
    drawn from the rotated Zipf head.  Policies that never evict (STATIC)
    keep serving the stale hot set while eviction-capable policies
    re-promote, and the prefix cache sees a hit-rate cliff at the drift —
    so the drift is observable in engine metrics, not just token content.

    (The pre-ISSUE-8 generator rotated only the token *identities* inside
    otherwise-private prompts; with identical arrival/length schedules no
    modeled metric could distinguish it from ``steady_zipfian``, which is
    exactly the identical-rows bug BENCH_serving.json exposed.)

    ``hot_len`` should be a page multiple so the hot head is shareable at
    page granularity; the arrival/length schedule intentionally matches
    ``steady_zipfian`` so any metric difference is attributable to the key
    distribution alone."""
    assert 0 < hot_len < prompt_len
    rng = np.random.default_rng(seed)
    split = int(n_requests * drift_at)
    head_a = _zipf_tokens(rng, vocab, hot_len)
    head_b = _zipf_tokens(rng, vocab, hot_len, head_offset=vocab // 2)
    reqs = []
    for i in range(n_requests):
        head = head_a if i < split else head_b
        offset = 0 if i < split else vocab // 2
        tail = _zipf_tokens(rng, vocab, prompt_len - hot_len,
                            head_offset=offset)
        reqs.append(Request(rid=i, arrival=i * gap,
                            prompt=np.concatenate([head, tail]),
                            max_new_tokens=max_new_tokens))
    return reqs


def shared_system_prompt(vocab: int, n_requests: int = 12, sys_len: int = 64,
                         user_len: int = 16, max_new_tokens: int = 16,
                         gap: int = 2, seed: int = 4) -> list[Request]:
    """Every request = one fixed system prefix + a unique user tail — the
    serving twin of the paper's hottest-row concentration, and the scenario
    the prefix-sharing acceptance (>= 40% prefill tokens saved) is measured
    on.  ``sys_len`` should be a page multiple so the whole system block is
    shareable at page granularity."""
    rng = np.random.default_rng(seed)
    sys_block = _zipf_tokens(rng, vocab, sys_len)
    return [Request(rid=i, arrival=i * gap,
                    prompt=np.concatenate(
                        [sys_block, _zipf_tokens(rng, vocab, user_len)]),
                    max_new_tokens=max_new_tokens)
            for i in range(n_requests)]


def multi_turn_chat(vocab: int, n_sessions: int = 3, turns: int = 3,
                    base_len: int = 24, turn_len: int = 16,
                    max_new_tokens: int = 8, think_gap: int = 24,
                    seed: int = 5) -> list[Request]:
    """Chat sessions whose follow-up turns re-arrive carrying the full
    history as the prompt: turn t's prompt = turn t-1's prompt + a
    deterministic stand-in for the assistant reply + a fresh user turn, so
    consecutive turns of a session share a growing page-aligned prefix.
    ``think_gap`` ticks separate a session's turns (user think time)."""
    rng = np.random.default_rng(seed)
    reqs, rid = [], 0
    for s in range(n_sessions):
        hist = _zipf_tokens(rng, vocab, base_len)
        for t in range(turns):
            reqs.append(Request(rid=rid, arrival=s * 2 + t * think_gap,
                                prompt=hist.copy(),
                                max_new_tokens=max_new_tokens))
            rid += 1
            hist = np.concatenate([hist,
                                   _zipf_tokens(rng, vocab, turn_len)])
    return sorted(reqs, key=lambda r: (r.arrival, r.rid))


def mixed_prefix(vocab: int, n_requests: int = 12, sys_len: int = 32,
                 user_len: int = 16, max_new_tokens: int = 8,
                 gap: int = 3, seed: int = 6) -> list[Request]:
    """Interleaved sharing profiles: a shared-system-prompt stream, a chat
    session re-arriving with growing history, and lone one-shot requests —
    the admission path must win on the sharers without taxing the loners."""
    rng = np.random.default_rng(seed)
    sys_block = _zipf_tokens(rng, vocab, sys_len)
    hist = _zipf_tokens(rng, vocab, sys_len)
    reqs = []
    for i in range(n_requests):
        kind = i % 3
        if kind == 0:      # shared system prompt + unique tail
            prompt = np.concatenate([sys_block,
                                     _zipf_tokens(rng, vocab, user_len)])
        elif kind == 1:    # chat session: history grows every visit
            prompt = hist.copy()
            hist = np.concatenate([hist, _zipf_tokens(rng, vocab, user_len)])
        else:              # loner: nothing shareable
            prompt = _zipf_tokens(rng, vocab, sys_len + user_len)
        reqs.append(Request(rid=i, arrival=i * gap, prompt=prompt,
                            max_new_tokens=max_new_tokens))
    return reqs


def long_context_summarize(vocab: int, n_requests: int = 6,
                           doc_len: int = 192, question_len: int = 16,
                           max_new_tokens: int = 8, gap: int = 4,
                           seed: int = 7) -> list[Request]:
    """Few slots, very long prompts: every request carries the SAME long
    document plus a short unique question (summarize/QA-over-document
    traffic).  The regime where a dense per-slot KV master hurt most —
    each tenant re-stored the whole document — and where the pool-native
    engine (ISSUE 5) wins most: the document's pages are stored once,
    shared by every slot, and each slot maps only the pages its request
    can touch.  ``doc_len`` should be a page multiple so the whole
    document is shareable at page granularity."""
    rng = np.random.default_rng(seed)
    doc = _zipf_tokens(rng, vocab, doc_len)
    return [Request(rid=i, arrival=i * gap,
                    prompt=np.concatenate(
                        [doc, _zipf_tokens(rng, vocab, question_len)]),
                    max_new_tokens=max_new_tokens)
            for i in range(n_requests)]


SCENARIOS = {
    "steady_zipfian": steady_zipfian,
    "bursty": bursty,
    "long_context_stragglers": long_context_stragglers,
    "shifting_hotspot": shifting_hotspot,
    "shared_system_prompt": shared_system_prompt,
    "multi_turn_chat": multi_turn_chat,
    "mixed_prefix": mixed_prefix,
    "long_context_summarize": long_context_summarize,
}
