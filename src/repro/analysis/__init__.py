"""repro-lint: a jaxpr/HLO invariant engine for the tiered serving stack.

The stack's headline guarantee — fused/gather/dense read paths bit-identical
while most far rows are never materialized and the pool is the single source
of truth — used to be enforced by scattered one-off pins (a private jaxpr
shape walker here, an HLO grep there, a source grep in a third test).  This
package makes those checks a reusable static-analysis pass framework, the
way TL-DRAM's isolation-transistor scheme only works because segment-access
discipline is enforced mechanically, not by convention (PAPER.md):

  walker    : recursive jaxpr traversal that handles pjit / scan / while /
              cond / closed_call / custom_* / pallas_call nesting uniformly,
              collecting every equation with shapes, dtypes and a raw-KV
              taint lattice, plus HLO lowering/op-presence helpers
              (`repro.analysis.walker`).
  targets   : the registered jitted step factories the serving stack
              actually runs — dense/gather/fused decode, pool prefill,
              suffix prefill, the score walk, migration planning — built
              over a distinctive-dimension config matrix
              (`repro.analysis.targets`).
  passes    : invariant passes over the walked programs — no-dense-far-view,
              f32-accumulation, no-host-sync, vmem-budget, no-collectives
              (`repro.analysis.passes`) — and the AST pool-ownership linter
              over `src/` (`repro.analysis.ownership`).
  runner    : `run_analysis()` executes every applicable pass over every
              target, filters a committed baseline of accepted findings,
              and `python -m repro.analysis` turns the result into a JSON
              report with a non-zero exit on unwaived violations
              (`repro.analysis.runner`, `repro.analysis.__main__`).

Docs: docs/design.md §3 "Static invariants" (pass catalog, how to add a
pass, the baseline file format).
"""

from repro.analysis.report import (AnalysisReport, Violation, load_baseline,
                                   violation_key)
from repro.analysis.runner import run_analysis
from repro.analysis.walker import (collect_eqns, hlo_ops_present,
                                   intermediate_shapes, lower_hlo_text)

__all__ = [
    "AnalysisReport", "Violation", "violation_key", "load_baseline",
    "run_analysis", "collect_eqns", "intermediate_shapes",
    "lower_hlo_text", "hlo_ops_present",
]
