"""Continuous-batching tiered-KV serving runtime (docs/design.md §2c–2f)."""

from repro.serve.engine import (DataParallelEngine, ServingConfig,
                                ServingEngine, sequential_baseline)
from repro.serve.metrics import (CostModel, ServingReport,
                                 merge_lane_reports, percentiles)
from repro.serve.prefix import PrefixStats, RadixPrefixCache
from repro.serve.trace import SCENARIOS, Request

__all__ = [
    "DataParallelEngine", "ServingConfig", "ServingEngine",
    "sequential_baseline",
    "CostModel", "ServingReport", "merge_lane_reports", "percentiles",
    "PrefixStats", "RadixPrefixCache",
    "SCENARIOS", "Request",
]
