"""Benchmark driver: one section per paper table/figure plus the TPU-adapted
tiered-runtime benches and the roofline summary (if dry-run artifacts exist).

Output format: ``name,us_per_call,values...`` CSV per row.

  python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
from pathlib import Path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller request counts for CI")
    args = ap.parse_args(argv)

    from benchmarks import paper_figures, tiered_runtime_bench

    print("# --- paper figures/tables (TL-DRAM reproduction) ---")
    paper_figures.run_all(quick=args.quick)

    print("# --- tiered runtime (TPU adaptation, beyond-paper) ---")
    tiered_runtime_bench.run_all()

    art = Path("artifacts/dryrun")
    if art.exists() and any(art.glob("*.json")):
        print("# --- roofline (from multi-pod dry-run artifacts) ---")
        from repro.launch import roofline
        cells = roofline.load_cells(art, "single")
        for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
            print(f"roofline,{c.arch},{c.shape},{c.compute_s*1e3:.2f}ms,"
                  f"{c.memory_s*1e3:.2f}ms,{c.collective_s*1e3:.2f}ms,"
                  f"{c.bound},{c.roofline_fraction:.3f}")
    else:
        print("# roofline: no dry-run artifacts (run repro.launch.dryrun)")


if __name__ == "__main__":
    main()
