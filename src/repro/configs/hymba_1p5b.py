"""Hymba-1.5B: hybrid-head decoder — parallel attention + Mamba heads.

[arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Most attention layers use a sliding window (sub-quadratic),
which is what qualifies the arch for the 500k-token decode shape.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    sliding_window=1024,
    ssm=SSMConfig(d_state=16, n_heads=25, head_dim=64),
    source="arXiv:2411.13676; hf",
)
