"""Trace-generator + accounting invariants (ISSUE 8 satellite bugfixes).

Three bench lies, pinned red-first:

1. ``shifting_hotspot`` produced row-for-row identical metrics to
   ``steady_zipfian``: the generator rotated only *token identities*, which
   no modeled metric can observe (every request's KV pages were private, so
   the engine saw identical arrival/length schedules and identical page
   traffic).  The fixed generator gives every prompt a page-aligned shared
   hot head whose identity rotates at the drift point — the drift now shows
   up in prefix-cache traffic, prefill token counts, and latency columns.

2. ``kv_live_ratio`` exceeded 1.0 on ``long_context_summarize``: the
   accounting charged the near-tier *derived copies* against a
   dense-equivalent denominator that never included a near tier.  Live
   bytes are referenced pool pages only (the pool is the single source of
   truth; near rows are duplicates of pool bytes, reported separately as
   ``kv_bytes_near``), and the engine asserts ``live <= dense_equiv`` every
   tick.

3. ``prefix_hit_rate`` was 0.0 in every matrix cell: ``serving_bench``'s
   matrix config left ``share_prefix`` off — covered by the bench itself
   (see ``benchmarks/serving_bench.bench_scenarios``) and by the
   engine-visible drift test below, which only observes the drift *through*
   the radix cache.
"""

import numpy as np

import jax
import pytest

from repro.configs.registry import ARCHS
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer
from repro.serve import ServingConfig, ServingEngine
from repro.serve.trace import SCENARIOS


def _arch_params(seed=0):
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(seed), arch)
    return arch, params


class TestShiftingHotspotDrift:
    def test_traces_differ_in_request_key_distribution(self):
        """Red test for the identical-rows bug: the two scenarios must be
        the SAME arrival/length schedule (controlled variables) but a
        DIFFERENT request/key distribution — shifting_hotspot concentrates
        every phase's prompts on one shared page-aligned hot head, and the
        head rotates at the drift point."""
        kw = dict(n_requests=12, prompt_len=24, max_new_tokens=16, gap=2)
        hot = SCENARIOS["shifting_hotspot"](256, **kw)
        steady = SCENARIOS["steady_zipfian"](256, **kw)
        assert [r.arrival for r in hot] == [r.arrival for r in steady]
        assert [len(r.prompt) for r in hot] == \
            [len(r.prompt) for r in steady]
        page = 16
        p1 = [r.prompt for r in hot[:6]]
        p2 = [r.prompt for r in hot[6:]]
        # each phase shares one page-aligned hot head ...
        assert all((p[:page] == p1[0][:page]).all() for p in p1), \
            "phase-1 prompts must share a hot head (key concentration)"
        assert all((p[:page] == p2[0][:page]).all() for p in p2), \
            "phase-2 prompts must share a hot head"
        # ... and the head actually drifts
        assert (p1[0][:page] != p2[0][:page]).any(), \
            "the hotspot-drift parameter is being ignored"
        # steady_zipfian draws independent prompts: no shared head
        sp = [r.prompt for r in steady]
        assert not all((p[:page] == sp[0][:page]).all() for p in sp[1:])
        # the tails stay unique within a phase (it's a hotspot, not a
        # duplicate-request trace)
        assert any((p1[0][page:] != p[page:]).any() for p in p1[1:])

    def test_drift_is_engine_visible(self):
        """The drift must reach the *metrics*, not just token content: with
        the prefix cache on (the bench matrix config), shifting_hotspot and
        steady_zipfian produce different prefill/hit columns, and the drift
        costs hits relative to a never-drifting hotspot."""
        arch, params = _arch_params()
        kw = dict(n_requests=8, prompt_len=24, max_new_tokens=8, gap=2)
        tier = TieredKVConfig(page=16, near_pages=2, interval=4,
                              policy="BBC")

        def run(name):
            cfg = ServingConfig(n_slots=3, max_len=64, prefill_bucket=16,
                                tier=tier, share_prefix=True)
            trace = SCENARIOS[name](arch.vocab, **kw)
            return ServingEngine(params, arch, cfg).run(trace, name)

        hot = run("shifting_hotspot")
        steady = run("steady_zipfian")
        assert hot.prefix_hit_tokens > 0, \
            "hotspot concentration must produce prefix hits"
        assert (hot.prefill_tokens, hot.prefix_hit_tokens) != \
            (steady.prefill_tokens, steady.prefix_hit_tokens), \
            "shifting_hotspot must not reproduce steady_zipfian's row"


class TestKVLiveInvariant:
    def test_kv_live_ratio_never_exceeds_dense_equiv(self):
        """Red test for the 1.042 bug: fill every slot to max_len so the
        pool holds exactly the dense-equivalent rows — the near-tier copies
        must NOT be double-counted on top.  The engine also asserts
        live <= dense_equiv per tick (this run would raise)."""
        arch, params = _arch_params(seed=2)
        tier = TieredKVConfig(page=16, near_pages=2, interval=4,
                              policy="BBC")
        cfg = ServingConfig(n_slots=2, max_len=80, prefill_bucket=16,
                            tier=tier)
        rng = np.random.default_rng(3)
        from repro.serve.trace import Request
        trace = [Request(rid=i, arrival=0,
                         prompt=rng.integers(0, arch.vocab, 56).astype(
                             np.int32),
                         max_new_tokens=16)
                 for i in range(2)]
        rep = ServingEngine(params, arch, cfg).run(trace, "full_slots")
        # both slots map their full demand: pool == dense exactly, and the
        # near copies may not tip it over 1.0 (the 1.042 bug)
        assert rep.kv_live_ratio == 1.0, rep.kv_live_ratio
        # near copies are still accounted — just in their own column
        assert rep.migrations > 0 and rep.kv_bytes_near > 0

    def test_matrix_summarize_cell_stays_at_or_below_one(self):
        """The exact regime the bench exposed: shared long document, every
        slot mapping the whole range.  Sharing keeps live well below dense;
        the per-tick assertion keeps it <= 1.0 forever."""
        arch, params = _arch_params(seed=3)
        tier = TieredKVConfig(page=16, near_pages=2, interval=4,
                              policy="BBC")
        cfg = ServingConfig(n_slots=3, max_len=64, prefill_bucket=16,
                            tier=tier, share_prefix=True)
        trace = SCENARIOS["long_context_summarize"](
            arch.vocab, n_requests=4, doc_len=32, question_len=16,
            max_new_tokens=8, gap=2)
        rep = ServingEngine(params, arch, cfg).run(trace, "summarize")
        assert rep.kv_live_ratio <= 1.0 + 1e-12
        assert rep.kv_live_ratio < 0.9   # sharing must actually save bytes
