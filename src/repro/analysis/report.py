"""Violation records, reports, and the committed-baseline mechanism.

A violation's identity must survive unrelated edits: baselines key on
``pass:rule:where:detail`` (no line numbers), so an accepted finding stays
waived until the offending construct itself moves or disappears.  Unused
baseline entries are reported so stale waivers rot loudly, not silently.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path


@dataclass
class Violation:
    """One invariant violation found by a pass."""
    pass_name: str          # e.g. "f32-accumulation"
    rule: str               # machine-readable sub-rule, e.g. "low-prec-dot"
    where: str              # target name or "file.py::qualname"
    detail: str             # stable human-readable description
    source: str = ""        # best-effort "file:line" (NOT part of the key)
    waived: bool = False    # matched a baseline entry

    @property
    def key(self) -> str:
        return violation_key(self.pass_name, self.rule, self.where,
                             self.detail)


def violation_key(pass_name: str, rule: str, where: str, detail: str) -> str:
    return f"{pass_name}:{rule}:{where}:{detail}"


def load_baseline(path: str | Path) -> dict[str, str]:
    """Baseline file: JSON object mapping violation keys -> reason strings.
    Missing file means an empty baseline."""
    p = Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    waivers = data.get("waivers", data) if isinstance(data, dict) else {}
    return {str(k): str(v) for k, v in waivers.items()}


def load_allowed_axes(path: str | Path) -> dict[str, tuple[str, ...]]:
    """The baseline's declared mesh axes per target: ``allowed_axes`` maps
    target name -> list of axis names whose jaxpr collectives the
    no-collectives pass accepts (the mesh-sharded read path's by-design
    'model'-axis gathers).  Committed next to the waivers so declaring an
    axis is a reviewable act, not a code default."""
    p = Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    axes = data.get("allowed_axes", {}) if isinstance(data, dict) else {}
    return {str(k): tuple(str(a) for a in v) for k, v in axes.items()}


@dataclass
class AnalysisReport:
    """The full result of one analysis run."""
    violations: list[Violation] = field(default_factory=list)
    passes_run: list[str] = field(default_factory=list)
    targets_run: list[str] = field(default_factory=list)
    unused_baseline: list[str] = field(default_factory=list)
    kernel_mode: str = ""

    def apply_baseline(self, baseline: dict[str, str]) -> None:
        used = set()
        for v in self.violations:
            if v.key in baseline:
                v.waived = True
                used.add(v.key)
        self.unused_baseline = sorted(set(baseline) - used)

    @property
    def active(self) -> list[Violation]:
        return [v for v in self.violations if not v.waived]

    @property
    def ok(self) -> bool:
        return not self.active

    def as_dict(self) -> dict:
        return {
            "ok": self.ok,
            "kernel_mode": self.kernel_mode,
            "passes_run": self.passes_run,
            "targets_run": self.targets_run,
            "n_violations": len(self.active),
            "n_waived": sum(1 for v in self.violations if v.waived),
            "violations": [asdict(v) | {"key": v.key}
                           for v in self.violations],
            "unused_baseline": self.unused_baseline,
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.as_dict(), indent=indent)
