"""Modeled byte-cost accounting + report types for the serving engine.

The cost model extends the tier cost landscape (`repro.tier.costs`, units:
relative byte-costs — only ratios matter) from the per-access level to the
per-decode-step level:

  step cost     = ``step_overhead``                (weight streaming: decode
                                                    is weight-bandwidth-bound
                                                    at small batch — the term
                                                    continuous batching
                                                    amortizes)
                + per-slot KV read cost            (near tokens at
                                                    ``near_cost``, the rest of
                                                    the live prefix
                                                    gather-addressed at
                                                    ``far_cost``)
  prefill cost  = ``prefill_token_cost`` x prompt tokens + ``step_overhead``
  migration     = pages moved x page x ``migrate_cost`` (the IST bill)

Latency-per-token is the modeled-clock gap between a token and the previous
token of the same sequence (first token: gap since the request's arrival —
queueing delay included), which is how serving systems report inter-token
latency and TTFT.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.tier import TierCosts
from repro.core.tiered_kv import DEFAULT_COSTS


@dataclass
class CostModel:
    step_overhead: float = 2048.0   # weight-stream cost per decode step
    prefill_token_cost: float = 2.0
    tier: TierCosts = DEFAULT_COSTS

    def decode_step_cost(self, near_tokens: np.ndarray,
                         live_tokens: np.ndarray,
                         kv_shards: int = 1) -> float:
        """near_tokens/live_tokens: per-active-slot arrays (near <= live).

        ``kv_shards``: number of devices the KV pool is head-sharded
        across (docs/design.md §2h).  Each device streams only its
        1/kv_shards slice of the KV bytes, so the KV term divides; the
        ``step_overhead`` weight stream does NOT — weights are replicated
        on every device of the mesh."""
        far = np.maximum(live_tokens - near_tokens, 0)
        kv = (near_tokens * self.tier.near_cost + far * self.tier.far_cost)
        return float(self.step_overhead + kv.sum() / max(int(kv_shards), 1))

    def prefill_cost(self, prompt_tokens: int) -> float:
        return self.step_overhead + self.prefill_token_cost * prompt_tokens

    def chunk_prefill_cost(self, chunk_tokens: int) -> float:
        """Prefill tokens piggybacked on a decode tick (Sarathi-style
        chunked prefill): the chunk's tokens are billed to the tick, the
        step overhead is NOT — the chunk shares the tick's weight stream.
        A prefill-only tick (no active decoders) still pays
        ``prefill_cost`` (it streams the weights for nobody else)."""
        return self.prefill_token_cost * chunk_tokens

    def migration_cost(self, pages_moved: int, page: int) -> float:
        return float(pages_moved) * page * self.tier.migrate_cost


def percentiles(xs, qs=(50, 99)) -> tuple[float, ...]:
    if not len(xs):
        return tuple(float("nan") for _ in qs)
    return tuple(float(np.percentile(np.asarray(xs, np.float64), q))
                 for q in qs)


@dataclass
class ServingReport:
    scenario: str
    policy: str
    n_requests: int
    tokens: int = 0
    steps: int = 0                   # batched decode steps executed
    wall_s: float = 0.0
    modeled_time: float = 0.0        # byte-cost clock at completion
    token_latencies: list = field(default_factory=list)   # modeled units
    ttfts: list = field(default_factory=list)   # first-token latencies
                                                # (queueing + prefill)
    near_hit_mass: list = field(default_factory=list)     # per planning pass
    migrations: int = 0
    outputs: dict = field(default_factory=dict)           # rid -> [tokens]
    slot_history: dict = field(default_factory=dict)      # slot -> [rids]
    max_read_err: float = 0.0        # tiered read-path verification residual
    # prefix-sharing accounting (zero when sharing is off)
    prefill_tokens: int = 0          # tokens actually prefilled (suffixes)
    prefill_tokens_full: int = 0     # tokens a no-sharing engine prefills
    prefix_hit_tokens: int = 0       # prompt tokens served from cached pages
    prefix_lookups: int = 0
    prefix_hits: int = 0
    # far-tier rows read per decode step, accumulated over the run (per
    # sequence per step, layer-invariant).  The fused walk touches only
    # live, non-promoted pages; the materializing path touches the whole
    # (B, n_pages*page) far view regardless (ISSUE 4 acceptance).
    far_rows_touched: int = 0        # what the configured read path touched
    far_rows_host: int = 0           # independent host-side shadow of the
                                     # fused walk (device/host parity pin)
    far_rows_dense: int = 0          # what a materializing path would touch
    # live-KV accounting (ISSUE 5): with the pool as the single source of
    # truth, what the engine actually keeps resident vs what the retired
    # dense per-slot master would have held.  Near-tier rows are *derived
    # copies* of pool bytes (TL-DRAM's near segment is the same mat behind
    # an isolation transistor, not extra capacity) — they are accounted in
    # their own column, never against the dense-equiv denominator, which
    # never included a near tier either (the kv_live_ratio > 1.0 bench lie,
    # ISSUE 8 satellite).
    kv_bytes_live: int = 0           # PEAK referenced-pool bytes over the
                                     # run (all layers, K and V)
    kv_bytes_near: int = 0           # peak occupied near-tier copy bytes
    kv_bytes_cached: int = 0         # peak prefix-retained idle bytes
                                     # (reclaimable cache, not live state)
    kv_bytes_dense_equiv: int = 0    # L * n_slots * max_len rows x2 — the
                                     # dense master's fixed footprint
    # overlap accounting (ISSUE 8 tentpole)
    prefill_chunks: int = 0          # chunked-prefill programs launched
    migration_deferrals: int = 0     # planning passes skipped by the
                                     # cost-aware deferral gate
    migration_stall: float = 0.0     # modeled time the background
                                     # migration lane was saturated

    @property
    def tokens_per_s_wall(self) -> float:
        return self.tokens / max(self.wall_s, 1e-9)

    @property
    def tokens_per_cost(self) -> float:
        """Modeled throughput: tokens per unit of byte-cost."""
        return self.tokens / max(self.modeled_time, 1e-9)

    @property
    def mean_hit_mass(self) -> float:
        return float(np.mean(self.near_hit_mass)) if self.near_hit_mass \
            else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens whose KV came from the prefix cache."""
        return self.prefix_hit_tokens / max(self.prefill_tokens_full, 1)

    @property
    def prefill_saved_frac(self) -> float:
        """Fraction of prefill tokens the sharing path avoided computing."""
        return 1.0 - self.prefill_tokens / max(self.prefill_tokens_full, 1)

    @property
    def p50_ttft(self) -> float:
        return percentiles(self.ttfts, qs=(50,))[0]

    @property
    def p99_lat(self) -> float:
        return percentiles(self.token_latencies, qs=(99,))[0]

    @property
    def kv_live_ratio(self) -> float:
        """Peak live KV bytes as a fraction of the dense-equivalent master
        (<= 1.0 ALWAYS — each slot maps at most its max_len of pages and
        shared pages count once; the engine asserts this per tick.  The
        shared/long-prefix traces pin <= 0.6)."""
        if self.kv_bytes_dense_equiv == 0:
            return 0.0
        return self.kv_bytes_live / self.kv_bytes_dense_equiv

    @property
    def far_rows_saved_frac(self) -> float:
        """Fraction of far-view rows the configured read path did NOT touch
        vs the materializing baseline (0.0 for the dense path itself, and
        0.0 for runs that tracked no far-row accounting at all, e.g. the
        sequential baseline)."""
        if self.far_rows_dense == 0:
            return 0.0
        return 1.0 - self.far_rows_touched / self.far_rows_dense

    def summary_row(self) -> tuple:
        p50, p99 = percentiles(self.token_latencies)
        return (self.scenario, self.policy, self.tokens,
                round(self.tokens_per_s_wall, 1),
                round(self.tokens_per_cost * 1e3, 3),
                round(self.mean_hit_mass, 3), self.migrations,
                round(p50, 1), round(p99, 1),
                round(self.prefix_hit_rate, 3), self.prefill_tokens,
                round(self.p50_ttft, 1), self.far_rows_touched,
                self.kv_bytes_live, round(self.kv_live_ratio, 3))

    HEADER = ("scenario", "policy", "tokens", "tok/s_wall",
              "tok/kcost_modeled", "near_hit_mass", "migrations",
              "p50_lat", "p99_lat", "prefix_hit_rate", "prefill_toks",
              "p50_ttft", "far_rows", "kv_bytes_live", "kv_live_ratio")


def merge_lane_reports(lanes: list) -> "ServingReport":
    """Fold per-replica lane reports into one fleet-level ServingReport.

    Data-parallel serving (docs/design.md §2h) runs R independent engine
    replicas, each with its own slot pool and modeled byte-cost clock.
    Counters sum; latency/TTFT samples concatenate (each sample is already
    on its own lane's clock); peak-byte columns sum (each lane owns
    distinct HBM); ``modeled_time`` is the MAX lane clock — the fleet is
    done when its slowest lane is — so ``tokens_per_cost`` reflects the
    per-device weight stream running R-wide in parallel.
    """
    if not lanes:
        raise ValueError("merge_lane_reports: no lanes")
    head = lanes[0]
    merged = ServingReport(
        scenario=head.scenario, policy=head.policy,
        n_requests=sum(r.n_requests for r in lanes))
    for f in ("tokens", "steps", "migrations", "prefill_tokens",
              "prefill_tokens_full", "prefix_hit_tokens", "prefix_lookups",
              "prefix_hits", "far_rows_touched", "far_rows_host",
              "far_rows_dense", "kv_bytes_live", "kv_bytes_near",
              "kv_bytes_cached", "kv_bytes_dense_equiv", "prefill_chunks",
              "migration_deferrals"):
        setattr(merged, f, sum(getattr(r, f) for r in lanes))
    merged.wall_s = max(r.wall_s for r in lanes)
    merged.modeled_time = max(r.modeled_time for r in lanes)
    merged.migration_stall = sum(r.migration_stall for r in lanes)
    merged.max_read_err = max(r.max_read_err for r in lanes)
    for r in lanes:
        merged.token_latencies.extend(r.token_latencies)
        merged.ttfts.extend(r.ttfts)
        merged.near_hit_mass.extend(r.near_hit_mass)
        merged.outputs.update(r.outputs)
    for i, r in enumerate(lanes):
        for slot, rids in r.slot_history.items():
            merged.slot_history[(i, slot)] = rids
    return merged
