"""Serve a reduced model with the continuous-batching tiered-KV engine.

Replays a steady-Zipfian arrival trace through ``repro.serve``: requests
are admitted into a fixed slot pool (prefill-into-slot), one batched decode
step with ragged per-slot positions serves every in-flight sequence, and
the BBC policy migrates hot KV pages of the shared far pool into the
global near tier on a background cadence.  Then replays a
shared-system-prompt trace with the radix prefix cache on: admissions
reuse the system prompt's pool pages and prefill only each request's
suffix — fewer prefill tokens, better TTFT, bit-identical outputs.
Finally re-serves the first trace with ``fused_kernel=True``: every decode
layer reads through the page-table-walking Pallas kernel (no far-view
materialization; docs/design.md §2e) — same tokens, a fraction of the far
rows touched.

  PYTHONPATH=src python examples/serve_tiered_kv.py
"""

import jax

from repro.configs.registry import ARCHS
from repro.core.tiered_kv import TieredKVConfig
from repro.models import transformer
from repro.serve import (ServingConfig, ServingEngine, percentiles,
                         sequential_baseline)
from repro.serve.trace import shared_system_prompt, steady_zipfian


def main():
    arch = ARCHS["qwen3-1.7b"].reduced()
    params = transformer.init_params(jax.random.key(0), arch)
    tier = TieredKVConfig(page=16, near_pages=2, interval=4, policy="BBC")
    cfg = ServingConfig(n_slots=4, max_len=64, prefill_bucket=16, tier=tier,
                        verify_tiered_read=True)
    trace = steady_zipfian(arch.vocab, n_requests=8, prompt_len=20,
                           max_new_tokens=12, gap=2)

    print(f"serving {len(trace)} requests on {cfg.n_slots} slots "
          f"({arch.name} reduced, policy={tier.policy})...")
    eng = ServingEngine(params, arch, cfg)
    eng.run(trace, "warmup")                  # compile outside the report
    rep = eng.run(trace, "steady_zipfian")

    p50, p99 = percentiles(rep.token_latencies)
    print(f"tokens={rep.tokens} decode_steps={rep.steps} "
          f"tok/s={rep.tokens_per_s_wall:.1f}")
    print(f"near-tier hit mass={rep.mean_hit_mass:.3f} "
          f"migrations={rep.migrations}")
    print(f"modeled latency/token p50={p50:.0f} p99={p99:.0f} "
          f"(byte-cost units)")
    print(f"tiered read-path max|err| vs monolithic: {rep.max_read_err:.2e}")
    print("slot reuse:", {s: rids for s, rids in rep.slot_history.items()})

    base = sequential_baseline(params, arch, trace, cfg)
    match = all(rep.outputs[r] == base.outputs[r] for r in rep.outputs)
    print(f"outputs identical to greedy_generate: {match}")
    print("request 0 tokens:", rep.outputs[0])

    # -- shared-prefix serving: radix cache over the far page pool ----------
    ssp_tier = TieredKVConfig(page=16, near_pages=4, interval=4,
                              policy="BBC")
    ssp = shared_system_prompt(arch.vocab, n_requests=8, sys_len=48,
                               user_len=12, max_new_tokens=8, gap=2)
    base_cfg = ServingConfig(n_slots=4, max_len=96, prefill_bucket=16,
                             tier=ssp_tier)
    share_cfg = ServingConfig(n_slots=4, max_len=96, prefill_bucket=16,
                              tier=ssp_tier, share_prefix=True)
    print("\nshared-system-prompt trace (48-token shared prefix), "
          "sharing OFF vs ON...")
    rep_off = ServingEngine(params, arch, base_cfg).run(ssp, "ssp")
    rep_on = ServingEngine(params, arch, share_cfg).run(ssp, "ssp")
    print(f"prefilled tokens: {rep_off.prefill_tokens} -> "
          f"{rep_on.prefill_tokens} "
          f"({rep_on.prefill_saved_frac:.0%} saved; "
          f"prefix hit rate {rep_on.prefix_hit_rate:.0%})")
    print(f"modeled p50 TTFT: {rep_off.p50_ttft:.0f} -> "
          f"{rep_on.p50_ttft:.0f}")
    print("outputs identical with sharing on:",
          rep_off.outputs == rep_on.outputs)
    print(f"peak live KV (pool is the only store): "
          f"{rep_on.kv_bytes_live} bytes = "
          f"{rep_on.kv_live_ratio:.2f}x the dense-equivalent master")

    # -- fused page-table-walking read path (ISSUE 4) -----------------------
    fused_tier = TieredKVConfig(page=16, near_pages=2, interval=4,
                                policy="BBC", fused_kernel=True)
    fused_cfg = ServingConfig(n_slots=4, max_len=64, prefill_bucket=16,
                              tier=fused_tier)
    print("\nsame steady-Zipfian trace through the FUSED walk kernel...")
    rep_f = ServingEngine(params, arch, fused_cfg).run(trace,
                                                       "steady_zipfian")
    print(f"outputs identical to the dense path: "
          f"{rep_f.outputs == rep.outputs}")
    print(f"far rows touched: {rep_f.far_rows_touched} "
          f"(dense path would touch {rep_f.far_rows_dense}; "
          f"{rep_f.far_rows_saved_frac:.0%} never read)")


if __name__ == "__main__":
    main()
