"""Pool-ownership AST linter over ``src/`` (the fifth shipped pass).

The page pool is the single source of truth for KV bytes (PR 5); this
linter enforces the discipline around it at the source level, where jaxpr
passes cannot see:

  deny-list      : names that must never reappear in ``src/`` — APIs whose
                   existence implies a dense per-slot KV mirror.  Replaces
                   (and generalizes) the ``refresh_pool_from_slots`` grep
                   pin that lived in tests/test_pool_native.py.
  alloc-release  : every module that takes page references must also give
                   them back — a module calling ``allocate``/``acquire``
                   without ``release``/``drop_cached``, ``retain`` without
                   ``drop_cached``, or ``paged_pin_pages`` without
                   ``paged_release_pages`` leaks pool pages by construction
                   (the shutdown orphan sweep would catch it dynamically;
                   this catches it at review time).
  tick-host-pull : the serving engine's per-tick methods are flagged for
                   host pulls (``np.asarray``/``np.array``/
                   ``.block_until_ready``/``jax.device_get``) — each is a
                   device sync on the token clock.  Legitimate sites (the
                   emitted-token pull, interval-amortized planning reads)
                   are waived in the committed baseline, so NEW pulls fail
                   loudly.
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.report import Violation

# APIs banned from src/: name -> why.
DENY_APIS = {
    "refresh_pool_from_slots":
        "re-derives the pool from a dense per-slot KV master — that master "
        "was retired in the pool-ownership inversion (PR 5); the pool IS "
        "the source of truth",
    "refresh_pool_from_cache":
        "same dense-mirror pattern under another name",
}

# (needs, satisfied-by): module-level reference-pairing rules.
PAIR_RULES = (
    (("allocate", "acquire"), ("release", "drop_cached"),
     "takes page refs but never releases"),
    (("retain",), ("drop_cached",),
     "retains cached pages but never drops"),
    (("paged_pin_pages",), ("paged_release_pages",),
     "pins pages into the near tier but never releases their tier state"),
)

# Per-tick methods, by class: these run on the decode token clock.
# Boundary methods (_admit/_retire, __init__, shutdown sweeps) are
# deliberately NOT listed — they run per request, not per token.
TICK_METHODS = {
    "ServingEngine": ("run", "_maintain", "_flush_mapping", "_pin_static",
                      "_far_rows_shadow", "_account_kv_bytes"),
}

# Host-pull callees flagged inside tick methods.
HOST_PULL_CALLS = ("np.asarray", "np.array", "jax.device_get",
                   ".block_until_ready")


def _callee_name(func: ast.AST) -> str:
    """Dotted name of a call target, best effort ('np.asarray',
    '.block_until_ready' for method calls on expressions)."""
    parts = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return "." + ".".join(reversed(parts)) if parts else ""


def _names_referenced(tree: ast.AST):
    """Every identifier a module mentions: names, attributes, defs,
    imports — the surface the deny-list matches against."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            yield node.id, node
        elif isinstance(node, ast.Attribute):
            yield node.attr, node
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            yield node.name, node
        elif isinstance(node, ast.alias):
            yield (node.asname or node.name).split(".")[-1], node


def _called_names(tree: ast.AST) -> set[str]:
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _callee_name(node.func)
            if name:
                out.add(name.split(".")[-1])
    return out


def _tick_method_pulls(tree: ast.AST):
    """(class.method, callee, lineno) for host pulls in tick methods."""
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or cls.name not in TICK_METHODS:
            continue
        ticks = TICK_METHODS[cls.name]
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    or fn.name not in ticks:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                callee = _callee_name(node.func)
                for pull in HOST_PULL_CALLS:
                    if callee == pull or (pull.startswith(".")
                                          and callee.endswith(pull)):
                        yield (f"{cls.name}.{fn.name}", pull, node.lineno)


def lint_ownership(root: str | Path) -> list[Violation]:
    """Run the three ownership rules over every ``*.py`` under ``root``."""
    root = Path(root)
    viols: list[Violation] = []
    for path in sorted(root.rglob("*.py")):
        if "analysis" in path.parts:
            continue          # the linter's own deny-list strings
        rel = str(path.relative_to(root.parent.parent)
                  if root.parent.parent in path.parents else path)
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError as e:
            viols.append(Violation(
                pass_name="pool-ownership", rule="syntax-error", where=rel,
                detail=f"unparseable module: {e.msg}"))
            continue

        seen_deny = set()
        for name, node in _names_referenced(tree):
            if name in DENY_APIS and name not in seen_deny:
                seen_deny.add(name)
                viols.append(Violation(
                    pass_name="pool-ownership", rule="deny-list",
                    where=rel, detail=f"`{name}` is banned: "
                                      f"{DENY_APIS[name]}",
                    source=f"{rel}:{getattr(node, 'lineno', 0)}"))

        called = _called_names(tree)
        for needs, satisfies, why in PAIR_RULES:
            hit = sorted(set(needs) & called)
            if hit and not (set(satisfies) & called):
                viols.append(Violation(
                    pass_name="pool-ownership", rule="unpaired-ref",
                    where=rel,
                    detail=f"calls {hit} but none of {list(satisfies)}: "
                           f"{why}"))

        seen_pulls = set()
        for qual, pull, lineno in _tick_method_pulls(tree):
            key = (qual, pull)
            if key in seen_pulls:
                continue
            seen_pulls.add(key)
            viols.append(Violation(
                pass_name="pool-ownership", rule="tick-host-pull",
                where=f"{rel}::{qual}",
                detail=f"host pull via {pull}",
                source=f"{rel}:{lineno}"))
    return viols
