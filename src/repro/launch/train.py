"""Training step factory + single-host training driver.

``make_train_step`` builds the pure (params, opt_state, batch) -> ... step
used both by the real trainer (examples/quickstart.py) and the multi-pod
dry-run (AOT lowering with ShapeDtypeStructs).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.optim import adamw


@dataclass(frozen=True)
class TrainConfig:
    remat: str = "full"            # 'full' | 'dots' | 'none'
    adamw: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    warmup_steps: int = 100
    total_steps: int = 10_000
    aux_weight: float = 0.01
    grad_dtype: str = "f32"        # 'bf16' halves DP-reduction wire bytes


def make_train_step(arch: ArchConfig, cfg: TrainConfig):
    schedule = adamw.cosine_schedule(cfg.adamw.lr, cfg.warmup_steps,
                                     cfg.total_steps)

    def train_step(params, opt_state, batch):
        if cfg.grad_dtype == "bf16":
            # Differentiate w.r.t. a bf16 copy of the params: cotangents —
            # and therefore the cross-replica gradient reductions GSPMD
            # inserts inside the layer loop — are bf16 end to end (half the
            # wire bytes).  A post-hoc cast cannot do this: the reduction
            # has already happened in f32 inside the loop (refuted in
            # docs/experiments.md §Perf kimi iter 1).
            params_c = jax.tree.map(
                lambda a: a.astype(jnp.bfloat16)
                if a.dtype == jnp.float32 and a.ndim > 1 else a, params)
            (loss, metrics), grads = jax.value_and_grad(
                transformer.loss_fn, has_aux=True)(
                    params_c, batch, arch, remat=cfg.remat,
                    aux_weight=cfg.aux_weight)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                transformer.loss_fn, has_aux=True)(
                    params, batch, arch, remat=cfg.remat,
                    aux_weight=cfg.aux_weight)
        lr = schedule(opt_state["step"])
        params, opt_state, opt_metrics = adamw.update(
            params, grads, opt_state, cfg.adamw, lr)
        return params, opt_state, {
            "loss": loss, "nll": metrics["nll"], "aux": metrics["aux"],
            "lr": lr, **opt_metrics}

    return train_step


def init_all(key, arch: ArchConfig, cfg: TrainConfig):
    params = transformer.init_params(key, arch)
    opt_state = adamw.init(params, cfg.adamw)
    return params, opt_state
